//! Wire protocol for `wattchmen serve`: newline-delimited JSON over TCP.
//!
//! One request per line, one response per line; a connection may pipeline
//! any number of requests before closing.  Three commands:
//!
//!   {"cmd":"predict","arch":"cloudlab-v100","workload":"hotspot",
//!    "mode":"pred","duration_s":90}       → prediction (or error)
//!   {"cmd":"status"}                      → counters (served, batches, …)
//!   {"cmd":"metrics"}                     → the same counters rendered in
//!                                           Prometheus text exposition
//!                                           format (in the "body" field)
//!   {"cmd":"shutdown"}                    → ack, then the server drains
//!
//! The `text` field of a predict response is byte-identical to the line
//! `wattchmen predict` prints for the same workload — both render through
//! [`render_line`], and both compute through `model::predict_many`.

use crate::model::{Mode, Prediction};
use crate::util::json::{parse, Json};

/// Arch assumed when a predict request omits `arch`.
pub const DEFAULT_ARCH: &str = "cloudlab-v100";

/// A parsed client request.
#[derive(Clone, Debug)]
pub enum Request {
    Predict {
        arch: String,
        workload: String,
        mode: Mode,
        /// Workload scaling target; `None` means the server default (the
        /// CLI's `WORKLOAD_SECS` measurement protocol).
        duration_s: Option<f64>,
    },
    Status,
    Metrics,
    Shutdown,
}

/// Snapshot of the serve counters, for `status` / `metrics` rendering.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServiceCounters {
    pub served: usize,
    pub batched_predict_calls: usize,
    pub table_reloads: usize,
    pub profile_cache_hits: usize,
    pub profile_cache_misses: usize,
}

/// Render the counters in Prometheus text exposition format (one
/// HELP/TYPE header per family; all families are monotonic counters).
pub fn prometheus_text(c: &ServiceCounters) -> String {
    let mut out = String::new();
    let families: [(&str, &str, usize); 5] = [
        (
            "wattchmen_predictions_served_total",
            "Predict requests answered successfully.",
            c.served,
        ),
        (
            "wattchmen_batched_predict_calls_total",
            "Coalesced predict_many calls issued.",
            c.batched_predict_calls,
        ),
        (
            "wattchmen_table_reloads_total",
            "Energy-table hot reloads from disk.",
            c.table_reloads,
        ),
        (
            "wattchmen_profile_cache_hits_total",
            "Memoized profile_app lookups served from cache.",
            c.profile_cache_hits,
        ),
        (
            "wattchmen_profile_cache_misses_total",
            "profile_app computations on cache miss.",
            c.profile_cache_misses,
        ),
    ];
    for (name, help, value) in families {
        out.push_str(&format!(
            "# HELP {name} {help}\n# TYPE {name} counter\n{name} {value}\n"
        ));
    }
    out
}

pub fn parse_mode(s: &str) -> Result<Mode, String> {
    match s {
        "direct" => Ok(Mode::Direct),
        "pred" => Ok(Mode::Pred),
        m => Err(format!("unknown mode '{m}' (direct|pred)")),
    }
}

pub fn mode_tag(mode: Mode) -> &'static str {
    match mode {
        Mode::Direct => "direct",
        Mode::Pred => "pred",
    }
}

/// Parse one request line.  Errors are plain strings so the server can
/// ship them back verbatim in an error response.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let j = parse(line).map_err(|e| format!("bad JSON request: {e}"))?;
    let cmd = j
        .get("cmd")
        .and_then(Json::as_str)
        .ok_or_else(|| "request needs a string 'cmd' field (predict|status|shutdown)".to_string())?;
    match cmd {
        "predict" => {
            let arch = j
                .get("arch")
                .and_then(Json::as_str)
                .unwrap_or(DEFAULT_ARCH)
                .to_string();
            let workload = j
                .get("workload")
                .and_then(Json::as_str)
                .ok_or_else(|| "predict needs a 'workload' field (see `wattchmen list`)".to_string())?
                .to_string();
            let mode = parse_mode(j.get("mode").and_then(Json::as_str).unwrap_or("pred"))?;
            let duration_s = j.get("duration_s").and_then(Json::as_f64);
            Ok(Request::Predict {
                arch,
                workload,
                mode,
                duration_s,
            })
        }
        "status" => Ok(Request::Status),
        "metrics" => Ok(Request::Metrics),
        "shutdown" => Ok(Request::Shutdown),
        other => Err(format!(
            "unknown cmd '{other}' (predict|status|metrics|shutdown)"
        )),
    }
}

/// Client-side helper: build a predict request line's JSON.
pub fn predict_request(arch: &str, workload: &str, mode: Mode) -> Json {
    Json::obj(vec![
        ("cmd", Json::Str("predict".into())),
        ("arch", Json::Str(arch.into())),
        ("workload", Json::Str(workload.into())),
        ("mode", Json::Str(mode_tag(mode).into())),
    ])
}

/// The one-line summary `wattchmen predict` prints per workload.  Shared
/// between the CLI and the served `text` field so the two are
/// byte-identical by construction.
pub fn render_line(p: &Prediction) -> String {
    format!(
        "{:<18} total {:>9.1} J  (base {:>8.1} J + dynamic {:>8.1} J)  coverage {:>5.1}%  runtime {:>6.1} s",
        p.workload,
        p.energy_j,
        p.base_j,
        p.dynamic_j,
        100.0 * p.coverage,
        p.duration_s
    )
}

pub fn prediction_json(p: &Prediction) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("workload", Json::Str(p.workload.clone())),
        ("energy_j", Json::Num(p.energy_j)),
        ("base_j", Json::Num(p.base_j)),
        ("dynamic_j", Json::Num(p.dynamic_j)),
        ("coverage", Json::Num(p.coverage)),
        ("duration_s", Json::Num(p.duration_s)),
        (
            "by_bucket",
            Json::Obj(
                p.by_bucket
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Num(*v)))
                    .collect(),
            ),
        ),
        ("text", Json::Str(render_line(p))),
    ])
}

/// Wrap a Prometheus exposition body for the JSON-per-line wire: scrapers
/// behind the TCP protocol extract `body` and serve it under the declared
/// content type.
pub fn metrics_json(body: &str) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        (
            "content_type",
            Json::Str("text/plain; version=0.0.4".into()),
        ),
        ("body", Json::Str(body.into())),
    ])
}

pub fn error_json(msg: &str) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(msg.into())),
    ])
}

pub fn ack_json(msg: &str) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("ack", Json::Str(msg.into())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    #[test]
    fn predict_request_roundtrips() {
        let line = predict_request("summit-v100", "hotspot", Mode::Direct).to_string_compact();
        match parse_request(&line).unwrap() {
            Request::Predict {
                arch,
                workload,
                mode,
                duration_s,
            } => {
                assert_eq!(arch, "summit-v100");
                assert_eq!(workload, "hotspot");
                assert_eq!(mode, Mode::Direct);
                assert_eq!(duration_s, None);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn defaults_and_explicit_duration() {
        let r = parse_request(r#"{"cmd":"predict","workload":"hotspot","duration_s":45}"#).unwrap();
        match r {
            Request::Predict {
                arch,
                mode,
                duration_s,
                ..
            } => {
                assert_eq!(arch, DEFAULT_ARCH);
                assert_eq!(mode, Mode::Pred);
                assert_eq!(duration_s, Some(45.0));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn bad_requests_are_descriptive_errors() {
        assert!(parse_request("not json").unwrap_err().contains("bad JSON"));
        assert!(parse_request(r#"{"cmd":"predict"}"#)
            .unwrap_err()
            .contains("workload"));
        assert!(parse_request(r#"{"cmd":"frobnicate"}"#)
            .unwrap_err()
            .contains("unknown cmd"));
        assert!(parse_request(r#"{"cmd":"predict","workload":"x","mode":"best"}"#)
            .unwrap_err()
            .contains("unknown mode"));
    }

    #[test]
    fn status_and_shutdown_parse() {
        assert!(matches!(
            parse_request(r#"{"cmd":"status"}"#).unwrap(),
            Request::Status
        ));
        assert!(matches!(
            parse_request(r#"{"cmd":"metrics"}"#).unwrap(),
            Request::Metrics
        ));
        assert!(matches!(
            parse_request(r#"{"cmd":"shutdown"}"#).unwrap(),
            Request::Shutdown
        ));
    }

    #[test]
    fn prometheus_rendering_is_exposition_format() {
        let c = ServiceCounters {
            served: 12,
            batched_predict_calls: 3,
            table_reloads: 1,
            profile_cache_hits: 10,
            profile_cache_misses: 2,
        };
        let text = prometheus_text(&c);
        // One HELP + TYPE + sample line per family, counters only.
        assert_eq!(text.lines().count(), 15, "{text}");
        assert!(text.contains(
            "# HELP wattchmen_predictions_served_total Predict requests answered successfully.\n\
             # TYPE wattchmen_predictions_served_total counter\n\
             wattchmen_predictions_served_total 12\n"
        ));
        assert!(text.contains("wattchmen_batched_predict_calls_total 3\n"));
        assert!(text.contains("wattchmen_table_reloads_total 1\n"));
        assert!(text.contains("wattchmen_profile_cache_hits_total 10\n"));
        assert!(text.contains("wattchmen_profile_cache_misses_total 2\n"));
        assert!(text.ends_with('\n'));
        for line in text.lines() {
            assert!(
                line.starts_with('#') || line.starts_with("wattchmen_"),
                "stray line {line:?}"
            );
        }
        // The JSON wrapper carries the body verbatim.
        let j = metrics_json(&text);
        assert_eq!(j.get("body").unwrap().as_str(), Some(text.as_str()));
        assert_eq!(
            j.get("content_type").unwrap().as_str(),
            Some("text/plain; version=0.0.4")
        );
    }

    #[test]
    fn rendered_line_matches_cli_format() {
        let p = Prediction {
            workload: "hotspot".into(),
            energy_j: 12345.67,
            base_j: 7380.0,
            dynamic_j: 4965.67,
            coverage: 0.987,
            duration_s: 90.0,
            by_bucket: BTreeMap::new(),
            by_key: Vec::new(),
        };
        let line = render_line(&p);
        assert!(line.starts_with("hotspot "), "{line}");
        assert!(line.contains("total   12345.7 J"), "{line}");
        assert!(line.contains("coverage  98.7%"), "{line}");
        let j = prediction_json(&p);
        assert_eq!(j.get("text").unwrap().as_str(), Some(line.as_str()));
        assert_eq!(j.get("energy_j").unwrap().as_f64(), Some(12345.67));
    }
}
