//! Wire protocol for `wattchmen serve`: newline-delimited JSON over TCP.
//!
//! One request per line, one response per line; a connection may pipeline
//! any number of requests before closing.  Five commands:
//!
//!   {"cmd":"predict","arch":"cloudlab-v100","workload":"hotspot",
//!    "mode":"pred","duration_s":90}       → prediction (or error)
//!   {"cmd":"predict_all","arch":"cloudlab-v100","mode":"pred"}
//!                                         → the arch's whole evaluation
//!                                           suite in one response: a
//!                                           "predictions" array whose
//!                                           elements are byte-identical
//!                                           to the individual predict
//!                                           responses, plus a "text"
//!                                           field with the CLI's lines
//!   {"cmd":"status"}                      → counters (served, batches, …)
//!   {"cmd":"metrics"}                     → the same counters rendered in
//!                                           Prometheus text exposition
//!                                           format (in the "body" field)
//!   {"cmd":"shutdown"}                    → ack, then the server drains
//!
//! Two further commands are discovered through the v2 `capabilities`
//! handshake rather than the legacy hint string: `frames` (switch the
//! connection's frame dialect, see below) and `advise` (DVFS frequency
//! sweep — `{"cmd":"advise","v":2,"arch":…,"workload":…,"objective":
//! "min-energy"|"min-edp"|"power-cap","power_cap_w":…}` answers with
//! [`crate::advisor::report::advice_json`]'s payload, byte-identical to
//! `wattchmen advise --json`).  Both parse under v1 too; v1 responses
//! for the five legacy commands are unchanged byte-for-byte.
//!
//! `predict` and `predict_all` accept an optional `"deadline_ms"` field
//! (combined with the server-wide `--deadline-ms` budget by MINIMUM — a
//! client may tighten the operator's ceiling, never extend it): a request
//! that cannot be answered within its budget gets
//! `{"ok":false,"error":"deadline exceeded","elapsed_ms":…}` while the
//! server — and the rest of the request's coalesced batch — stays
//! healthy.  When the bounded request queue is full the server sheds load
//! with `{"ok":false,"error":"overloaded","retry_after_ms":…}` instead of
//! queueing without bound.  Every predict-family request that parses
//! lands in exactly one of the `served` / `rejected` /
//! `deadline_exceeded` / `request_errors` counters (status JSON and
//! Prometheus text); malformed lines get an error response and count
//! toward none.
//!
//! The `text` field of a predict response is byte-identical to the line
//! `wattchmen predict` prints for the same workload — both render through
//! [`render_line`], and both compute through `model::predict_many`.
//!
//! # Protocol versions
//!
//! A request may carry `"v":1` (or omit it — the default) or `"v":2`:
//!
//! * **v1** — the legacy dialect, answered byte-identically to pre-v2
//!   servers: flat string errors (`{"ok":false,"error":"…"}`, plus
//!   `retry_after_ms` / `elapsed_ms` where applicable) and a `status`
//!   body of bare counters.  Pinned by the conformance suite.
//! * **v2** — structured errors
//!   `{"ok":false,"error":{"code":…,"message":…}}` whose codes are
//!   [`crate::Error`]'s stable wire codes (the message is the same
//!   legacy string v1 ships), and a `capabilities` handshake object in
//!   the `status` response ([`capabilities_json`]).  Success responses
//!   are identical to v1's.
//!
//! Unknown versions are rejected with a v1-shaped `bad_request` error
//! (the server cannot know the client's dialect).

use std::time::Duration;

use crate::advisor::Objective;
use crate::error::Error;
use crate::model::{Mode, Prediction};
use crate::util::json::{parse, Json};

/// Arch assumed when a predict request omits `arch`.
pub const DEFAULT_ARCH: &str = "cloudlab-v100";

/// Largest accepted `deadline_ms` (one day).  Client-controlled values
/// above this are clamped — `Duration::from_secs_f64` would panic on an
/// overflowing (but finite) float, and such a budget means "no budget".
pub const MAX_DEADLINE_MS: f64 = 86_400_000.0;

/// Wire name of the default newline-delimited JSON framing.
pub const FRAMES_JSONL: &str = "jsonl";

/// Wire name of the length-prefixed binary framing (see `SERVE.md`).
pub const FRAMES_BIN1: &str = "bin1";

/// Wire dialect of one request (see the module docs).  Every response
/// builder takes the request's `Proto` so v1 clients keep receiving the
/// legacy bytes while v2 clients get structured errors.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Proto {
    #[default]
    V1,
    V2,
}

/// A parsed client request.
#[derive(Clone, Debug)]
pub enum Request {
    Predict {
        arch: String,
        workload: String,
        mode: Mode,
        /// Workload scaling target; `None` means the server default (the
        /// CLI's `WORKLOAD_SECS` measurement protocol).
        duration_s: Option<f64>,
        /// Per-request deadline budget; combined with the server-wide
        /// budget by minimum (`None` defers to the server's, if any).
        deadline: Option<Duration>,
    },
    /// Answer the arch's whole evaluation suite in one response.
    PredictAll {
        arch: String,
        mode: Mode,
        duration_s: Option<f64>,
        deadline: Option<Duration>,
    },
    /// DVFS frequency sweep: curves + sweet spots for the selection
    /// (`workload` matches by exact name or prefix; `None` = the whole
    /// suite) under the requested objective.
    Advise {
        arch: String,
        workload: Option<String>,
        mode: Mode,
        duration_s: Option<f64>,
        deadline: Option<Duration>,
        objective: Objective,
    },
    Status,
    Metrics,
    Shutdown,
    /// Switch the connection's frame dialect (`format`: `jsonl` or
    /// `bin1`).  The ack is written in the *old* dialect; every
    /// subsequent frame in both directions uses the new one (see
    /// SERVE.md §Negotiation).
    Frames { format: String },
}

/// Snapshot of the serve counters, for `status` / `metrics` rendering.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServiceCounters {
    pub served: usize,
    pub rejected: usize,
    pub deadline_exceeded: usize,
    pub request_errors: usize,
    pub batched_predict_calls: usize,
    pub table_reloads: usize,
    pub profile_cache_hits: usize,
    pub profile_cache_misses: usize,
    pub accept_errors: usize,
    /// Currently-open client connections (event-loop acceptor; the
    /// legacy thread-per-connection path does not track it).  A gauge.
    pub open_connections: usize,
    /// Connections closed by the header deadline (slow-loris guard).
    pub slow_client_closes: usize,
    /// Connections upgraded to the `bin1` binary frame dialect.
    pub frame_upgrades: usize,
}

/// Render the counters in Prometheus text exposition format (one
/// HELP/TYPE header per family).  These families are *metrics-only*:
/// the v1 `status` JSON keeps its original ten fields byte-identical
/// (pinned by `tests/protocol_v2.rs`), so new observability lands here.
pub fn prometheus_text(c: &ServiceCounters) -> String {
    let mut out = String::new();
    let families: [(&str, &str, &str, usize); 12] = [
        (
            "wattchmen_predictions_served_total",
            "Predict requests answered successfully.",
            "counter",
            c.served,
        ),
        (
            "wattchmen_requests_rejected_total",
            "Predict requests shed with an overloaded response (queue full).",
            "counter",
            c.rejected,
        ),
        (
            "wattchmen_deadline_exceeded_total",
            "Predict requests that missed their deadline budget.",
            "counter",
            c.deadline_exceeded,
        ),
        (
            "wattchmen_request_errors_total",
            "Predict requests answered with a non-deadline, non-overload error.",
            "counter",
            c.request_errors,
        ),
        (
            "wattchmen_batched_predict_calls_total",
            "Coalesced predict_many calls issued.",
            "counter",
            c.batched_predict_calls,
        ),
        (
            "wattchmen_table_reloads_total",
            "Energy-table hot reloads from disk.",
            "counter",
            c.table_reloads,
        ),
        (
            "wattchmen_profile_cache_hits_total",
            "Memoized profile_app lookups served from cache.",
            "counter",
            c.profile_cache_hits,
        ),
        (
            "wattchmen_profile_cache_misses_total",
            "profile_app computations on cache miss.",
            "counter",
            c.profile_cache_misses,
        ),
        (
            "wattchmen_accept_errors_total",
            "Listener accept() failures (e.g. fd exhaustion), backed off and retried.",
            "counter",
            c.accept_errors,
        ),
        (
            "wattchmen_open_connections",
            "Currently-open client connections (event-loop acceptor).",
            "gauge",
            c.open_connections,
        ),
        (
            "wattchmen_slow_client_closes_total",
            "Connections closed for exceeding the request header deadline.",
            "counter",
            c.slow_client_closes,
        ),
        (
            "wattchmen_frame_upgrades_total",
            "Connections upgraded to the bin1 binary frame dialect.",
            "counter",
            c.frame_upgrades,
        ),
    ];
    for (name, help, kind, value) in families {
        out.push_str(&format!(
            "# HELP {name} {help}\n# TYPE {name} {kind}\n{name} {value}\n"
        ));
    }
    out
}

/// Snapshot of the daemon's health and ledger, for its Prometheus
/// export (`wattchmen daemon --metrics-out`).  Energy fields carry the
/// integer-nanojoule ledger, so `attributed + idle + unattributed ==
/// total` holds exactly in the rendered text too.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DaemonMetrics {
    pub samples_total: u64,
    pub attributed_nj: u128,
    pub idle_nj: u128,
    pub unattributed_nj: u128,
    pub total_nj: u128,
    pub streams_healthy: u64,
    pub streams_degraded: u64,
    pub streams_stale: u64,
    pub worker_restarts: u64,
    pub workers_degraded: u64,
    pub duplicates_dropped: u64,
    pub out_of_order: u64,
    pub invalid_samples: u64,
    pub gaps_interpolated: u64,
    pub unbounded_gaps: u64,
    pub dropouts_injected: u64,
    pub export_failures: u64,
    pub checkpoint_writes: u64,
    pub checkpoint_failures: u64,
    pub config_reloads: u64,
    pub config_reload_errors: u64,
    pub config_stale: bool,
}

/// Render the daemon metrics in Prometheus text exposition format.
/// Stream/worker health and the staleness flag are gauges (they move
/// both ways); everything else is a monotonic counter.
pub fn daemon_prometheus_text(m: &DaemonMetrics) -> String {
    let counter = "counter";
    let gauge = "gauge";
    let families: [(&str, &str, &str, String); 22] = [
        (
            "wattchmen_daemon_samples_total",
            "Telemetry samples attributed (deduplicated).",
            counter,
            m.samples_total.to_string(),
        ),
        (
            "wattchmen_daemon_attributed_energy_nj_total",
            "Energy credited to tagged kernels, in nanojoules.",
            counter,
            m.attributed_nj.to_string(),
        ),
        (
            "wattchmen_daemon_idle_energy_nj_total",
            "Energy credited to idle (untagged) time, in nanojoules.",
            counter,
            m.idle_nj.to_string(),
        ),
        (
            "wattchmen_daemon_unattributed_energy_nj_total",
            "Energy accrued over unbounded gaps and invalid samples, in nanojoules.",
            counter,
            m.unattributed_nj.to_string(),
        ),
        (
            "wattchmen_daemon_energy_nj_total",
            "Total integrated stream energy, in nanojoules (equals the three buckets).",
            counter,
            m.total_nj.to_string(),
        ),
        (
            "wattchmen_daemon_streams_healthy",
            "Streams currently in the healthy state.",
            gauge,
            m.streams_healthy.to_string(),
        ),
        (
            "wattchmen_daemon_streams_degraded",
            "Streams currently in the degraded state.",
            gauge,
            m.streams_degraded.to_string(),
        ),
        (
            "wattchmen_daemon_streams_stale",
            "Streams currently in the stale state.",
            gauge,
            m.streams_stale.to_string(),
        ),
        (
            "wattchmen_daemon_worker_restarts_total",
            "Worker panics caught and restarted by the supervisor.",
            counter,
            m.worker_restarts.to_string(),
        ),
        (
            "wattchmen_daemon_workers_degraded",
            "Workers parked after exhausting their restart budget.",
            gauge,
            m.workers_degraded.to_string(),
        ),
        (
            "wattchmen_daemon_duplicates_dropped_total",
            "Duplicate samples dropped before attribution.",
            counter,
            m.duplicates_dropped.to_string(),
        ),
        (
            "wattchmen_daemon_out_of_order_total",
            "Samples rejected for non-advancing timestamps.",
            counter,
            m.out_of_order.to_string(),
        ),
        (
            "wattchmen_daemon_invalid_samples_total",
            "Samples with NaN or negative power readings.",
            counter,
            m.invalid_samples.to_string(),
        ),
        (
            "wattchmen_daemon_gaps_interpolated_total",
            "Bounded gaps bridged by trapezoidal interpolation.",
            counter,
            m.gaps_interpolated.to_string(),
        ),
        (
            "wattchmen_daemon_unbounded_gaps_total",
            "Gaps past the bound, accrued to unattributed energy.",
            counter,
            m.unbounded_gaps.to_string(),
        ),
        (
            "wattchmen_daemon_dropouts_injected_total",
            "Sensor readings swallowed by injected dropouts.",
            counter,
            m.dropouts_injected.to_string(),
        ),
        (
            "wattchmen_daemon_export_failures_total",
            "Metrics export ticks that hit an I/O error.",
            counter,
            m.export_failures.to_string(),
        ),
        (
            "wattchmen_daemon_checkpoint_writes_total",
            "Checkpoints durably written (fsync + rename).",
            counter,
            m.checkpoint_writes.to_string(),
        ),
        (
            "wattchmen_daemon_checkpoint_failures_total",
            "Checkpoint write attempts that failed.",
            counter,
            m.checkpoint_failures.to_string(),
        ),
        (
            "wattchmen_daemon_config_reloads_total",
            "Successful stream-policy hot reloads.",
            counter,
            m.config_reloads.to_string(),
        ),
        (
            "wattchmen_daemon_config_reload_errors_total",
            "Rejected stream-policy reloads (kept the old config).",
            counter,
            m.config_reload_errors.to_string(),
        ),
        (
            "wattchmen_daemon_config_stale",
            "1 when the on-disk config is invalid and an older one is live.",
            gauge,
            if m.config_stale { "1" } else { "0" }.to_string(),
        ),
    ];
    let mut out = String::new();
    for (name, help, kind, value) in families {
        out.push_str(&format!(
            "# HELP {name} {help}\n# TYPE {name} {kind}\n{name} {value}\n"
        ));
    }
    out
}

pub fn parse_mode(s: &str) -> Result<Mode, Error> {
    match s {
        "direct" => Ok(Mode::Direct),
        "pred" => Ok(Mode::Pred),
        m => Err(Error::BadRequest(format!("unknown mode '{m}' (direct|pred)"))),
    }
}

pub fn mode_tag(mode: Mode) -> &'static str {
    match mode {
        Mode::Direct => "direct",
        Mode::Pred => "pred",
    }
}

/// Fields shared by `predict` and `predict_all`: arch, mode, and the two
/// client-controlled numbers, both validated here — `duration_s` feeds
/// workload scaling (a NaN would silently poison every downstream sum)
/// and a negative/NaN `deadline_ms` would panic `Duration::from_secs_f64`
/// on the request path.
fn predict_fields(j: &Json) -> Result<(String, Mode, Option<f64>, Option<Duration>), Error> {
    let arch = j
        .get("arch")
        .and_then(Json::as_str)
        .unwrap_or(DEFAULT_ARCH)
        .to_string();
    let mode = parse_mode(j.get("mode").and_then(Json::as_str).unwrap_or("pred"))?;
    let duration_s = match j.get("duration_s") {
        None => None,
        Some(v) => {
            let d = v
                .as_f64()
                .ok_or_else(|| Error::bad_request("duration_s must be a number"))?;
            if !d.is_finite() || d <= 0.0 {
                return Err(Error::BadRequest(format!(
                    "duration_s must be a positive finite number, got {d}"
                )));
            }
            Some(d)
        }
    };
    let deadline = match j.get("deadline_ms") {
        None => None,
        Some(v) => {
            let ms = v
                .as_f64()
                .ok_or_else(|| Error::bad_request("deadline_ms must be a number"))?;
            if !ms.is_finite() || ms < 0.0 {
                return Err(Error::BadRequest(format!(
                    "deadline_ms must be a non-negative finite number, got {ms}"
                )));
            }
            // Cap at a day: Duration::from_secs_f64 panics on overflow,
            // and any budget that long is "no budget" in practice.
            Some(Duration::from_secs_f64(ms.min(MAX_DEADLINE_MS) / 1000.0))
        }
    };
    Ok((arch, mode, duration_s, deadline))
}

/// Parse one request line into its wire dialect and request (or typed
/// error).  The `Proto` comes back even for malformed bodies so the
/// error response can be rendered in the client's dialect; a line whose
/// JSON (or `v` field) is itself unreadable defaults to v1.
pub fn parse_request(line: &str) -> (Proto, Result<Request, Error>) {
    let j = match parse(line) {
        Ok(j) => j,
        Err(e) => {
            return (
                Proto::V1,
                Err(Error::BadRequest(format!("bad JSON request: {e}"))),
            )
        }
    };
    let v = match j.get("v") {
        None => Proto::V1,
        Some(Json::Num(x)) if *x == 1.0 => Proto::V1,
        Some(Json::Num(x)) if *x == 2.0 => Proto::V2,
        Some(other) => {
            let shown = other.to_string_compact();
            return (
                Proto::V1,
                Err(Error::BadRequest(format!(
                    "unsupported protocol version {shown} (supported: 1, 2)"
                ))),
            );
        }
    };
    (v, parse_request_body(&j))
}

fn parse_request_body(j: &Json) -> Result<Request, Error> {
    let cmd = j.get("cmd").and_then(Json::as_str).ok_or_else(|| {
        Error::bad_request(
            "request needs a string 'cmd' field (predict|predict_all|status|metrics|shutdown)",
        )
    })?;
    match cmd {
        "predict" => {
            let (arch, mode, duration_s, deadline) = predict_fields(j)?;
            let workload = j
                .get("workload")
                .and_then(Json::as_str)
                .ok_or_else(|| {
                    Error::bad_request("predict needs a 'workload' field (see `wattchmen list`)")
                })?
                .to_string();
            Ok(Request::Predict {
                arch,
                workload,
                mode,
                duration_s,
                deadline,
            })
        }
        "predict_all" => {
            let (arch, mode, duration_s, deadline) = predict_fields(j)?;
            Ok(Request::PredictAll {
                arch,
                mode,
                duration_s,
                deadline,
            })
        }
        "advise" => {
            let (arch, mode, duration_s, deadline) = predict_fields(j)?;
            let workload = j.get("workload").and_then(Json::as_str).map(str::to_string);
            let name = j.get("objective").and_then(Json::as_str).unwrap_or("min-energy");
            let power_cap_w = match j.get("power_cap_w") {
                None => None,
                Some(v) => Some(v.as_f64().ok_or_else(|| {
                    Error::bad_request("power_cap_w must be a number (watts)")
                })?),
            };
            let objective = Objective::parse(name, power_cap_w)?;
            Ok(Request::Advise {
                arch,
                workload,
                mode,
                duration_s,
                deadline,
                objective,
            })
        }
        "status" => Ok(Request::Status),
        "metrics" => Ok(Request::Metrics),
        "shutdown" => Ok(Request::Shutdown),
        "frames" => {
            let format = j
                .get("format")
                .and_then(Json::as_str)
                .ok_or_else(|| {
                    Error::bad_request("frames needs a string 'format' field (jsonl|bin1)")
                })?
                .to_string();
            Ok(Request::Frames { format })
        }
        // The parenthetical hint is legacy v1 bytes pinned by
        // tests/protocol_v2.rs — `frames` is discovered through the v2
        // capabilities object instead of being appended here.
        other => Err(Error::BadRequest(format!(
            "unknown cmd '{other}' (predict|predict_all|status|metrics|shutdown)"
        ))),
    }
}

/// Client-side helper: build a predict request line's JSON.
pub fn predict_request(arch: &str, workload: &str, mode: Mode) -> Json {
    Json::obj(vec![
        ("cmd", Json::Str("predict".into())),
        ("arch", Json::Str(arch.into())),
        ("workload", Json::Str(workload.into())),
        ("mode", Json::Str(mode_tag(mode).into())),
    ])
}

/// Client-side helper: build a predict_all (whole evaluation suite)
/// request line's JSON.
pub fn predict_all_request(arch: &str, mode: Mode) -> Json {
    Json::obj(vec![
        ("cmd", Json::Str("predict_all".into())),
        ("arch", Json::Str(arch.into())),
        ("mode", Json::Str(mode_tag(mode).into())),
    ])
}

/// Client-side helper: build an advise (DVFS sweep) request line's JSON.
/// `workload` selects by exact name or prefix; `None` sweeps the suite.
pub fn advise_request(arch: &str, workload: Option<&str>, mode: Mode, obj: &Objective) -> Json {
    let mut fields = vec![
        ("cmd", Json::Str("advise".into())),
        ("arch", Json::Str(arch.into())),
        ("mode", Json::Str(mode_tag(mode).into())),
        ("objective", Json::Str(obj.wire_name().into())),
    ];
    if let Some(w) = workload {
        fields.push(("workload", Json::Str(w.into())));
    }
    if let Some(cap) = obj.power_cap_w() {
        fields.push(("power_cap_w", Json::Num(cap)));
    }
    Json::obj(fields)
}

/// The advise success response: the advisor's shared payload builder,
/// verbatim — `wattchmen advise --json` prints exactly these bytes for
/// the same request (the predict/`render_line` discipline, applied to
/// the whole payload).
pub fn advise_json(advice: &crate::advisor::Advice) -> Json {
    crate::advisor::report::advice_json(advice)
}

/// The one-line summary `wattchmen predict` prints per workload.  Shared
/// between the CLI and the served `text` field so the two are
/// byte-identical by construction.
pub fn render_line(p: &Prediction) -> String {
    format!(
        "{:<18} total {:>9.1} J  (base {:>8.1} J + dynamic {:>8.1} J)  coverage {:>5.1}%  runtime {:>6.1} s",
        p.workload,
        p.energy_j,
        p.base_j,
        p.dynamic_j,
        100.0 * p.coverage,
        p.duration_s
    )
}

pub fn prediction_json(p: &Prediction) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("workload", Json::Str(p.workload.clone())),
        ("energy_j", Json::Num(p.energy_j)),
        ("base_j", Json::Num(p.base_j)),
        ("dynamic_j", Json::Num(p.dynamic_j)),
        ("coverage", Json::Num(p.coverage)),
        ("duration_s", Json::Num(p.duration_s)),
        (
            "by_bucket",
            Json::Obj(
                p.by_bucket
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Num(*v)))
                    .collect(),
            ),
        ),
        ("text", Json::Str(render_line(p))),
    ])
}

/// The whole-suite response: `predictions` holds one element per
/// workload in evaluation-suite order, each rendered by the *same*
/// [`prediction_json`] as an individual predict response (so the two are
/// byte-identical), and `text` is the suite rendering `wattchmen predict`
/// prints — one [`render_line`] per workload, newline-joined.
pub fn predict_all_json(arch: &str, preds: &[Prediction]) -> Json {
    let lines: Vec<String> = preds.iter().map(render_line).collect();
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("arch", Json::Str(arch.into())),
        ("count", Json::Num(preds.len() as f64)),
        ("predictions", Json::Arr(preds.iter().map(prediction_json).collect())),
        ("text", Json::Str(lines.join("\n"))),
    ])
}

/// The `error` field for one dialect: v1 ships the flat legacy string,
/// v2 the structured `{code, message}` object.
fn error_field(v: Proto, e: &Error) -> Json {
    match v {
        Proto::V1 => Json::Str(e.to_string()),
        Proto::V2 => Json::obj(vec![
            ("code", Json::Str(e.code().into())),
            ("message", Json::Str(e.to_string())),
        ]),
    }
}

/// Generic failure response in the request's dialect.
pub fn error_response(v: Proto, e: &Error) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", error_field(v, e))])
}

/// Load-shed response: the bounded request queue is full.  The hint is
/// the server's linger window — one batch's worth of drain time.
pub fn overloaded_json(v: Proto, retry_after_ms: u64) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", error_field(v, &Error::Overloaded)),
        ("retry_after_ms", Json::Num(retry_after_ms as f64)),
    ])
}

/// Deadline-miss response: how long the request had been in flight when
/// the server gave up on it (always ≥ the requested budget).
pub fn deadline_error_json(v: Proto, elapsed: Duration) -> Json {
    // One decimal of milliseconds: stable to render, precise enough to
    // compare against the budget.
    let elapsed_ms = (elapsed.as_secs_f64() * 1e4).round() / 10.0;
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", error_field(v, &Error::DeadlineExceeded)),
        ("elapsed_ms", Json::Num(elapsed_ms)),
    ])
}

/// The protocol v2 `capabilities` handshake, shipped inside v2 `status`
/// responses: what this server speaks, so remote clients can negotiate
/// without guessing.
pub fn capabilities_json() -> Json {
    let strs = |items: &[&str]| Json::Arr(items.iter().map(|s| Json::Str((*s).into())).collect());
    Json::obj(vec![
        ("protocol_versions", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)])),
        (
            "commands",
            strs(&["predict", "predict_all", "advise", "status", "metrics", "shutdown", "frames"]),
        ),
        ("objectives", strs(&["min-energy", "min-edp", "power-cap"])),
        ("modes", strs(&["direct", "pred"])),
        ("frames", strs(&["jsonl", "bin1"])),
        ("error_codes", strs(&Error::CODES)),
        ("max_deadline_ms", Json::Num(MAX_DEADLINE_MS)),
        (
            "max_request_bytes",
            Json::Num(crate::service::MAX_REQUEST_BYTES as f64),
        ),
    ])
}

/// Wrap a Prometheus exposition body for the JSON-per-line wire: scrapers
/// behind the TCP protocol extract `body` and serve it under the declared
/// content type.
pub fn metrics_json(body: &str) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        (
            "content_type",
            Json::Str("text/plain; version=0.0.4".into()),
        ),
        ("body", Json::Str(body.into())),
    ])
}

pub fn error_json(msg: &str) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(msg.into())),
    ])
}

pub fn ack_json(msg: &str) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("ack", Json::Str(msg.into())),
    ])
}

/// Client-side helper: build the `frames` dialect-switch request (a v2
/// command — servers that predate it answer with the legacy unknown-cmd
/// error, which a client treats as "no upgrade").
pub fn frames_request(format: &str) -> Json {
    Json::obj(vec![
        ("cmd", Json::Str("frames".into())),
        ("format", Json::Str(format.into())),
        ("v", Json::Num(2.0)),
    ])
}

/// The `frames` ack: echoes the granted format.  Written in the *old*
/// dialect; the switch takes effect for every subsequent frame.
pub fn frames_ack_json(format: &str) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("frames", Json::Str(format.into())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    /// Parse helper: body of a well-formed line (any dialect).
    fn req(line: &str) -> Request {
        parse_request(line).1.unwrap()
    }

    /// Parse helper: error message of a malformed line.
    fn req_err(line: &str) -> String {
        parse_request(line).1.unwrap_err().to_string()
    }

    #[test]
    fn predict_request_roundtrips() {
        let line = predict_request("summit-v100", "hotspot", Mode::Direct).to_string_compact();
        match req(&line) {
            Request::Predict {
                arch,
                workload,
                mode,
                duration_s,
                deadline,
            } => {
                assert_eq!(arch, "summit-v100");
                assert_eq!(workload, "hotspot");
                assert_eq!(mode, Mode::Direct);
                assert_eq!(duration_s, None);
                assert_eq!(deadline, None);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn predict_all_request_roundtrips() {
        let line = predict_all_request("lonestar-a100", Mode::Pred).to_string_compact();
        match req(&line) {
            Request::PredictAll {
                arch,
                mode,
                duration_s,
                deadline,
            } => {
                assert_eq!(arch, "lonestar-a100");
                assert_eq!(mode, Mode::Pred);
                assert_eq!(duration_s, None);
                assert_eq!(deadline, None);
            }
            other => panic!("{other:?}"),
        }
        // Defaults mirror predict's.
        match req(r#"{"cmd":"predict_all"}"#) {
            Request::PredictAll { arch, mode, .. } => {
                assert_eq!(arch, DEFAULT_ARCH);
                assert_eq!(mode, Mode::Pred);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn deadline_ms_parses_and_is_validated() {
        match req(r#"{"cmd":"predict","workload":"x","deadline_ms":250}"#) {
            Request::Predict { deadline, .. } => {
                assert_eq!(deadline, Some(Duration::from_millis(250)));
            }
            other => panic!("{other:?}"),
        }
        // A zero budget is legal (expire immediately); negative, NaN (JSON
        // null), and non-numeric budgets are parse errors, NOT panics —
        // Duration::from_secs_f64 would abort the worker on them.
        match req(r#"{"cmd":"predict_all","deadline_ms":0}"#) {
            Request::PredictAll { deadline, .. } => assert_eq!(deadline, Some(Duration::ZERO)),
            other => panic!("{other:?}"),
        }
        for bad in [
            r#"{"cmd":"predict","workload":"x","deadline_ms":-1}"#,
            r#"{"cmd":"predict","workload":"x","deadline_ms":null}"#,
            r#"{"cmd":"predict","workload":"x","deadline_ms":"soon"}"#,
        ] {
            assert!(req_err(bad).contains("deadline_ms"), "{bad}");
        }
        // A finite-but-absurd budget is clamped, not a
        // Duration::from_secs_f64 panic.
        match req(r#"{"cmd":"predict","workload":"x","deadline_ms":1e300}"#) {
            Request::Predict { deadline, .. } => {
                assert_eq!(deadline, Some(Duration::from_secs_f64(MAX_DEADLINE_MS / 1000.0)));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn duration_s_is_validated() {
        for bad in [
            r#"{"cmd":"predict","workload":"x","duration_s":-90}"#,
            r#"{"cmd":"predict","workload":"x","duration_s":0}"#,
            r#"{"cmd":"predict","workload":"x","duration_s":null}"#,
            r#"{"cmd":"predict_all","duration_s":"long"}"#,
        ] {
            assert!(req_err(bad).contains("duration_s"), "{bad}");
        }
    }

    #[test]
    fn defaults_and_explicit_duration() {
        let r = req(r#"{"cmd":"predict","workload":"hotspot","duration_s":45}"#);
        match r {
            Request::Predict {
                arch,
                mode,
                duration_s,
                ..
            } => {
                assert_eq!(arch, DEFAULT_ARCH);
                assert_eq!(mode, Mode::Pred);
                assert_eq!(duration_s, Some(45.0));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn bad_requests_are_descriptive_bad_request_errors() {
        assert!(req_err("not json").contains("bad JSON"));
        assert!(req_err(r#"{"cmd":"predict"}"#).contains("workload"));
        assert!(req_err(r#"{"cmd":"frobnicate"}"#).contains("unknown cmd"));
        assert!(req_err(r#"{"cmd":"predict","workload":"x","mode":"best"}"#)
            .contains("unknown mode"));
        // Every parse failure carries the bad_request wire code.
        for bad in ["not json", r#"{"cmd":"predict"}"#, r#"{"cmd":"frobnicate"}"#] {
            assert_eq!(parse_request(bad).1.unwrap_err().code(), "bad_request");
        }
    }

    #[test]
    fn protocol_version_field_selects_the_dialect() {
        assert_eq!(parse_request(r#"{"cmd":"status"}"#).0, Proto::V1);
        assert_eq!(parse_request(r#"{"cmd":"status","v":1}"#).0, Proto::V1);
        assert_eq!(parse_request(r#"{"cmd":"status","v":2}"#).0, Proto::V2);
        // The dialect comes back even when the body is malformed, so the
        // error can be rendered in the client's dialect.
        let (v, body) = parse_request(r#"{"cmd":"frobnicate","v":2}"#);
        assert_eq!(v, Proto::V2);
        assert!(body.unwrap_err().to_string().contains("unknown cmd"));
        // Unknown versions are a v1-shaped bad_request.
        for bad in [r#"{"cmd":"status","v":3}"#, r#"{"cmd":"status","v":"two"}"#] {
            let (v, body) = parse_request(bad);
            assert_eq!(v, Proto::V1, "{bad}");
            let e = body.unwrap_err();
            assert_eq!(e.code(), "bad_request");
            assert!(e.to_string().contains("unsupported protocol version"), "{e}");
        }
    }

    #[test]
    fn error_responses_render_per_dialect() {
        let e = Error::unknown_workload("nosuch", "cloudlab-v100");
        let v1 = error_response(Proto::V1, &e);
        assert_eq!(
            v1.to_string_compact(),
            r#"{"error":"unknown workload 'nosuch' for cloudlab-v100 (see `wattchmen list`)","ok":false}"#
        );
        let v2 = error_response(Proto::V2, &e);
        let obj = v2.get("error").unwrap();
        assert_eq!(obj.get("code").unwrap().as_str(), Some("unknown_workload"));
        assert_eq!(obj.get("message").unwrap().as_str(), Some(e.to_string().as_str()));

        // Overload / deadline keep their extra fields in both dialects.
        let o1 = overloaded_json(Proto::V1, 10);
        assert_eq!(
            o1.to_string_compact(),
            r#"{"error":"overloaded","ok":false,"retry_after_ms":10}"#
        );
        let o2 = overloaded_json(Proto::V2, 10);
        assert_eq!(o2.get("retry_after_ms").unwrap().as_f64(), Some(10.0));
        assert_eq!(
            o2.get("error").unwrap().get("code").unwrap().as_str(),
            Some("overloaded")
        );
        let d2 = deadline_error_json(Proto::V2, Duration::from_micros(37_540));
        assert_eq!(d2.get("elapsed_ms").unwrap().as_f64(), Some(37.5));
        assert_eq!(
            d2.get("error").unwrap().get("code").unwrap().as_str(),
            Some("deadline_exceeded")
        );
    }

    #[test]
    fn capabilities_cover_the_surface() {
        let caps = capabilities_json();
        let versions = caps.get("protocol_versions").unwrap().as_arr().unwrap();
        assert_eq!(versions.len(), 2);
        let commands = caps.get("commands").unwrap().as_arr().unwrap();
        assert_eq!(commands.len(), 7);
        let commands: Vec<&str> = commands.iter().filter_map(Json::as_str).collect();
        assert!(commands.contains(&"advise"));
        let objectives = caps.get("objectives").unwrap().as_arr().unwrap();
        let objectives: Vec<&str> = objectives.iter().filter_map(Json::as_str).collect();
        assert_eq!(objectives, ["min-energy", "min-edp", "power-cap"]);
        let frames = caps.get("frames").unwrap().as_arr().unwrap();
        let frames: Vec<&str> = frames.iter().filter_map(Json::as_str).collect();
        assert_eq!(frames, ["jsonl", "bin1"]);
        let codes = caps.get("error_codes").unwrap().as_arr().unwrap();
        assert_eq!(codes.len(), Error::CODES.len());
        assert_eq!(
            caps.get("max_deadline_ms").unwrap().as_f64(),
            Some(MAX_DEADLINE_MS)
        );
    }

    #[test]
    fn advise_parses_with_defaults_and_validates_the_objective() {
        // The client helper round-trips through the parser.
        let line = advise_request("cloudlab-v100", Some("backprop"), Mode::Pred, &Objective::MinEdp)
            .to_string_compact();
        assert_eq!(
            line,
            r#"{"arch":"cloudlab-v100","cmd":"advise","mode":"pred","objective":"min-edp","workload":"backprop"}"#
        );
        match req(&line) {
            Request::Advise {
                arch,
                workload,
                mode,
                objective,
                ..
            } => {
                assert_eq!(arch, "cloudlab-v100");
                assert_eq!(workload.as_deref(), Some("backprop"));
                assert_eq!(mode, Mode::Pred);
                assert_eq!(objective, Objective::MinEdp);
            }
            other => panic!("{other:?}"),
        }
        // Defaults mirror predict's; objective defaults to min-energy.
        match req(r#"{"cmd":"advise"}"#) {
            Request::Advise {
                arch,
                workload,
                objective,
                duration_s,
                deadline,
                ..
            } => {
                assert_eq!(arch, DEFAULT_ARCH);
                assert_eq!(workload, None);
                assert_eq!(objective, Objective::MinEnergy);
                assert_eq!(duration_s, None);
                assert_eq!(deadline, None);
            }
            other => panic!("{other:?}"),
        }
        // power-cap needs (and echoes) a positive finite cap.
        match req(r#"{"cmd":"advise","objective":"power-cap","power_cap_w":250}"#) {
            Request::Advise { objective, .. } => {
                assert_eq!(objective, Objective::EnergyUnderCap(250.0));
            }
            other => panic!("{other:?}"),
        }
        let cap_req = advise_request("v100", None, Mode::Pred, &Objective::EnergyUnderCap(250.0));
        assert_eq!(
            cap_req.to_string_compact(),
            r#"{"arch":"v100","cmd":"advise","mode":"pred","objective":"power-cap","power_cap_w":250}"#
        );
        // Bad objectives / caps are typed bad_request parse errors.
        for bad in [
            r#"{"cmd":"advise","objective":"frobnicate"}"#,
            r#"{"cmd":"advise","objective":"power-cap"}"#,
            r#"{"cmd":"advise","objective":"power-cap","power_cap_w":-1}"#,
            r#"{"cmd":"advise","objective":"power-cap","power_cap_w":"lots"}"#,
            r#"{"cmd":"advise","duration_s":0}"#,
            r#"{"cmd":"advise","deadline_ms":-1}"#,
        ] {
            assert_eq!(parse_request(bad).1.unwrap_err().code(), "bad_request", "{bad}");
        }
    }

    #[test]
    fn status_and_shutdown_parse() {
        assert!(matches!(req(r#"{"cmd":"status"}"#), Request::Status));
        assert!(matches!(req(r#"{"cmd":"metrics"}"#), Request::Metrics));
        assert!(matches!(req(r#"{"cmd":"shutdown"}"#), Request::Shutdown));
    }

    #[test]
    fn frames_parses_and_requires_format() {
        assert!(matches!(
            req(r#"{"cmd":"frames","format":"bin1","v":2}"#),
            Request::Frames { format } if format == "bin1"
        ));
        // The client helper builds exactly that shape.
        assert_eq!(
            frames_request("bin1").to_string_compact(),
            r#"{"cmd":"frames","format":"bin1","v":2}"#
        );
        let (_, parsed) = parse_request(r#"{"cmd":"frames"}"#);
        let msg = parsed.unwrap_err().to_string();
        assert!(msg.contains("format"), "{msg}");
        // The ack echoes the granted format.
        assert_eq!(
            frames_ack_json("bin1").to_string_compact(),
            r#"{"frames":"bin1","ok":true}"#
        );
    }

    #[test]
    fn prometheus_rendering_is_exposition_format() {
        let c = ServiceCounters {
            served: 12,
            rejected: 4,
            deadline_exceeded: 5,
            request_errors: 6,
            batched_predict_calls: 3,
            table_reloads: 1,
            profile_cache_hits: 10,
            profile_cache_misses: 2,
            accept_errors: 7,
            open_connections: 4096,
            slow_client_closes: 8,
            frame_upgrades: 9,
        };
        let text = prometheus_text(&c);
        // One HELP + TYPE + sample line per family.
        assert_eq!(text.lines().count(), 36, "{text}");
        assert!(text.contains(
            "# HELP wattchmen_predictions_served_total Predict requests answered successfully.\n\
             # TYPE wattchmen_predictions_served_total counter\n\
             wattchmen_predictions_served_total 12\n"
        ));
        assert!(text.contains("wattchmen_requests_rejected_total 4\n"));
        assert!(text.contains("wattchmen_deadline_exceeded_total 5\n"));
        assert!(text.contains("wattchmen_request_errors_total 6\n"));
        assert!(text.contains("wattchmen_batched_predict_calls_total 3\n"));
        assert!(text.contains("wattchmen_table_reloads_total 1\n"));
        assert!(text.contains("wattchmen_profile_cache_hits_total 10\n"));
        assert!(text.contains("wattchmen_profile_cache_misses_total 2\n"));
        assert!(text.contains("wattchmen_accept_errors_total 7\n"));
        // The connection gauge is typed gauge, not counter.
        assert!(text.contains(
            "# TYPE wattchmen_open_connections gauge\nwattchmen_open_connections 4096\n"
        ));
        assert!(text.contains("wattchmen_slow_client_closes_total 8\n"));
        assert!(text.contains("wattchmen_frame_upgrades_total 9\n"));
        assert!(text.ends_with('\n'));
        for line in text.lines() {
            assert!(
                line.starts_with('#') || line.starts_with("wattchmen_"),
                "stray line {line:?}"
            );
        }
        // The JSON wrapper carries the body verbatim.
        let j = metrics_json(&text);
        assert_eq!(j.get("body").unwrap().as_str(), Some(text.as_str()));
        assert_eq!(
            j.get("content_type").unwrap().as_str(),
            Some("text/plain; version=0.0.4")
        );
    }

    #[test]
    fn daemon_prometheus_rendering_pins_every_family() {
        let m = DaemonMetrics {
            samples_total: 3000,
            attributed_nj: 123_456_789_000,
            idle_nj: 9_876_543_210,
            unattributed_nj: 11,
            total_nj: 123_456_789_000 + 9_876_543_210 + 11,
            streams_healthy: 1,
            streams_degraded: 1,
            streams_stale: 0,
            worker_restarts: 4,
            workers_degraded: 0,
            duplicates_dropped: 2,
            out_of_order: 3,
            invalid_samples: 9,
            gaps_interpolated: 5,
            unbounded_gaps: 1,
            dropouts_injected: 27,
            export_failures: 2,
            checkpoint_writes: 6,
            checkpoint_failures: 1,
            config_reloads: 1,
            config_reload_errors: 1,
            config_stale: true,
        };
        let text = daemon_prometheus_text(&m);
        // 22 families, HELP + TYPE + sample each.
        assert_eq!(text.lines().count(), 66, "{text}");
        assert!(text.contains(
            "# HELP wattchmen_daemon_samples_total Telemetry samples attributed \
             (deduplicated).\n# TYPE wattchmen_daemon_samples_total counter\n\
             wattchmen_daemon_samples_total 3000\n"
        ));
        assert!(text.contains("wattchmen_daemon_attributed_energy_nj_total 123456789000\n"));
        assert!(text.contains("wattchmen_daemon_energy_nj_total 133333332221\n"));
        assert!(text.contains("# TYPE wattchmen_daemon_streams_healthy gauge\n"));
        assert!(text.contains("# TYPE wattchmen_daemon_workers_degraded gauge\n"));
        assert!(text.contains("# TYPE wattchmen_daemon_config_stale gauge\n"));
        assert!(text.contains("wattchmen_daemon_config_stale 1\n"));
        assert!(text.contains("# TYPE wattchmen_daemon_worker_restarts_total counter\n"));
        assert!(text.ends_with('\n'));
        for line in text.lines() {
            assert!(
                line.starts_with('#') || line.starts_with("wattchmen_daemon_"),
                "stray line {line:?}"
            );
        }
        // The default snapshot renders every value as zero.
        let zero = daemon_prometheus_text(&DaemonMetrics::default());
        assert_eq!(zero.lines().count(), 66);
        assert!(zero.contains("wattchmen_daemon_config_stale 0\n"));
    }

    #[test]
    fn v1_overload_and_deadline_responses_keep_the_legacy_shape() {
        let o = overloaded_json(Proto::V1, 10);
        assert_eq!(o.get("ok").unwrap(), &Json::Bool(false));
        assert_eq!(o.get("error").unwrap().as_str(), Some("overloaded"));
        assert_eq!(o.get("retry_after_ms").unwrap().as_f64(), Some(10.0));

        let d = deadline_error_json(Proto::V1, Duration::from_micros(37_540));
        assert_eq!(d.get("ok").unwrap(), &Json::Bool(false));
        assert_eq!(d.get("error").unwrap().as_str(), Some("deadline exceeded"));
        assert_eq!(d.get("elapsed_ms").unwrap().as_f64(), Some(37.5));
    }

    #[test]
    fn predict_all_elements_are_byte_identical_to_single_responses() {
        let mk = |name: &str, e: f64| Prediction {
            workload: name.into(),
            energy_j: e,
            base_j: e * 0.6,
            dynamic_j: e * 0.4,
            coverage: 0.9,
            duration_s: 90.0,
            by_bucket: BTreeMap::new(),
            by_key: Vec::new(),
        };
        let preds = vec![mk("hotspot", 1000.0), mk("backprop_k2_fixed", 2000.0)];
        let all = predict_all_json("cloudlab-v100", &preds);
        assert_eq!(all.get("ok").unwrap(), &Json::Bool(true));
        assert_eq!(all.get("count").unwrap().as_f64(), Some(2.0));
        let arr = all.get("predictions").unwrap().as_arr().unwrap();
        for (element, p) in arr.iter().zip(&preds) {
            assert_eq!(
                element.to_string_compact(),
                prediction_json(p).to_string_compact()
            );
        }
        let text = all.get("text").unwrap().as_str().unwrap();
        assert_eq!(
            text,
            format!("{}\n{}", render_line(&preds[0]), render_line(&preds[1]))
        );
    }

    #[test]
    fn rendered_line_matches_cli_format() {
        let p = Prediction {
            workload: "hotspot".into(),
            energy_j: 12345.67,
            base_j: 7380.0,
            dynamic_j: 4965.67,
            coverage: 0.987,
            duration_s: 90.0,
            by_bucket: BTreeMap::new(),
            by_key: Vec::new(),
        };
        let line = render_line(&p);
        assert!(line.starts_with("hotspot "), "{line}");
        assert!(line.contains("total   12345.7 J"), "{line}");
        assert!(line.contains("coverage  98.7%"), "{line}");
        let j = prediction_json(&p);
        assert_eq!(j.get("text").unwrap().as_str(), Some(line.as_str()));
        assert_eq!(j.get("energy_j").unwrap().as_f64(), Some(12345.67));
    }
}
