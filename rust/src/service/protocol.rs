//! Wire protocol for `wattchmen serve`: newline-delimited JSON over TCP.
//!
//! One request per line, one response per line; a connection may pipeline
//! any number of requests before closing.  Three commands:
//!
//!   {"cmd":"predict","arch":"cloudlab-v100","workload":"hotspot",
//!    "mode":"pred","duration_s":90}       → prediction (or error)
//!   {"cmd":"status"}                      → counters (served, batches, …)
//!   {"cmd":"shutdown"}                    → ack, then the server drains
//!
//! The `text` field of a predict response is byte-identical to the line
//! `wattchmen predict` prints for the same workload — both render through
//! [`render_line`], and both compute through `model::predict_many`.

use crate::model::{Mode, Prediction};
use crate::util::json::{parse, Json};

/// Arch assumed when a predict request omits `arch`.
pub const DEFAULT_ARCH: &str = "cloudlab-v100";

/// A parsed client request.
#[derive(Clone, Debug)]
pub enum Request {
    Predict {
        arch: String,
        workload: String,
        mode: Mode,
        /// Workload scaling target; `None` means the server default (the
        /// CLI's `WORKLOAD_SECS` measurement protocol).
        duration_s: Option<f64>,
    },
    Status,
    Shutdown,
}

pub fn parse_mode(s: &str) -> Result<Mode, String> {
    match s {
        "direct" => Ok(Mode::Direct),
        "pred" => Ok(Mode::Pred),
        m => Err(format!("unknown mode '{m}' (direct|pred)")),
    }
}

pub fn mode_tag(mode: Mode) -> &'static str {
    match mode {
        Mode::Direct => "direct",
        Mode::Pred => "pred",
    }
}

/// Parse one request line.  Errors are plain strings so the server can
/// ship them back verbatim in an error response.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let j = parse(line).map_err(|e| format!("bad JSON request: {e}"))?;
    let cmd = j
        .get("cmd")
        .and_then(Json::as_str)
        .ok_or_else(|| "request needs a string 'cmd' field (predict|status|shutdown)".to_string())?;
    match cmd {
        "predict" => {
            let arch = j
                .get("arch")
                .and_then(Json::as_str)
                .unwrap_or(DEFAULT_ARCH)
                .to_string();
            let workload = j
                .get("workload")
                .and_then(Json::as_str)
                .ok_or_else(|| "predict needs a 'workload' field (see `wattchmen list`)".to_string())?
                .to_string();
            let mode = parse_mode(j.get("mode").and_then(Json::as_str).unwrap_or("pred"))?;
            let duration_s = j.get("duration_s").and_then(Json::as_f64);
            Ok(Request::Predict {
                arch,
                workload,
                mode,
                duration_s,
            })
        }
        "status" => Ok(Request::Status),
        "shutdown" => Ok(Request::Shutdown),
        other => Err(format!("unknown cmd '{other}' (predict|status|shutdown)")),
    }
}

/// Client-side helper: build a predict request line's JSON.
pub fn predict_request(arch: &str, workload: &str, mode: Mode) -> Json {
    Json::obj(vec![
        ("cmd", Json::Str("predict".into())),
        ("arch", Json::Str(arch.into())),
        ("workload", Json::Str(workload.into())),
        ("mode", Json::Str(mode_tag(mode).into())),
    ])
}

/// The one-line summary `wattchmen predict` prints per workload.  Shared
/// between the CLI and the served `text` field so the two are
/// byte-identical by construction.
pub fn render_line(p: &Prediction) -> String {
    format!(
        "{:<18} total {:>9.1} J  (base {:>8.1} J + dynamic {:>8.1} J)  coverage {:>5.1}%  runtime {:>6.1} s",
        p.workload,
        p.energy_j,
        p.base_j,
        p.dynamic_j,
        100.0 * p.coverage,
        p.duration_s
    )
}

pub fn prediction_json(p: &Prediction) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("workload", Json::Str(p.workload.clone())),
        ("energy_j", Json::Num(p.energy_j)),
        ("base_j", Json::Num(p.base_j)),
        ("dynamic_j", Json::Num(p.dynamic_j)),
        ("coverage", Json::Num(p.coverage)),
        ("duration_s", Json::Num(p.duration_s)),
        (
            "by_bucket",
            Json::Obj(
                p.by_bucket
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Num(*v)))
                    .collect(),
            ),
        ),
        ("text", Json::Str(render_line(p))),
    ])
}

pub fn error_json(msg: &str) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(msg.into())),
    ])
}

pub fn ack_json(msg: &str) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("ack", Json::Str(msg.into())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    #[test]
    fn predict_request_roundtrips() {
        let line = predict_request("summit-v100", "hotspot", Mode::Direct).to_string_compact();
        match parse_request(&line).unwrap() {
            Request::Predict {
                arch,
                workload,
                mode,
                duration_s,
            } => {
                assert_eq!(arch, "summit-v100");
                assert_eq!(workload, "hotspot");
                assert_eq!(mode, Mode::Direct);
                assert_eq!(duration_s, None);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn defaults_and_explicit_duration() {
        let r = parse_request(r#"{"cmd":"predict","workload":"hotspot","duration_s":45}"#).unwrap();
        match r {
            Request::Predict {
                arch,
                mode,
                duration_s,
                ..
            } => {
                assert_eq!(arch, DEFAULT_ARCH);
                assert_eq!(mode, Mode::Pred);
                assert_eq!(duration_s, Some(45.0));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn bad_requests_are_descriptive_errors() {
        assert!(parse_request("not json").unwrap_err().contains("bad JSON"));
        assert!(parse_request(r#"{"cmd":"predict"}"#)
            .unwrap_err()
            .contains("workload"));
        assert!(parse_request(r#"{"cmd":"frobnicate"}"#)
            .unwrap_err()
            .contains("unknown cmd"));
        assert!(parse_request(r#"{"cmd":"predict","workload":"x","mode":"best"}"#)
            .unwrap_err()
            .contains("unknown mode"));
    }

    #[test]
    fn status_and_shutdown_parse() {
        assert!(matches!(
            parse_request(r#"{"cmd":"status"}"#).unwrap(),
            Request::Status
        ));
        assert!(matches!(
            parse_request(r#"{"cmd":"shutdown"}"#).unwrap(),
            Request::Shutdown
        ));
    }

    #[test]
    fn rendered_line_matches_cli_format() {
        let p = Prediction {
            workload: "hotspot".into(),
            energy_j: 12345.67,
            base_j: 7380.0,
            dynamic_j: 4965.67,
            coverage: 0.987,
            duration_s: 90.0,
            by_bucket: BTreeMap::new(),
            by_key: Vec::new(),
        };
        let line = render_line(&p);
        assert!(line.starts_with("hotspot "), "{line}");
        assert!(line.contains("total   12345.7 J"), "{line}");
        assert!(line.contains("coverage  98.7%"), "{line}");
        let j = prediction_json(&p);
        assert_eq!(j.get("text").unwrap().as_str(), Some(line.as_str()));
        assert_eq!(j.get("energy_j").unwrap().as_f64(), Some(12345.67));
    }
}
