//! Wire framing shared by the server (both acceptor paths) and
//! [`RemoteClient`](crate::engine::client::RemoteClient) — see SERVE.md
//! for the byte-level layouts and the negotiation sequence.
//!
//! Two dialects carry the same JSON request/response bodies:
//!
//! * [`FrameDialect::Jsonl`] — newline-delimited JSON, the v1/v2 legacy
//!   dialect every connection starts in.  Pinned byte-identical by
//!   `tests/protocol_v2.rs`.
//! * [`FrameDialect::Bin1`] — length-prefixed binary: a little-endian
//!   `u32` payload length, then one encoding-tag byte
//!   ([`FRAME_ENC_JSON`]), then the payload.  Negotiated per connection
//!   through the `frames` command (advertised in the protocol-v2
//!   `capabilities` object); the tag byte reserves room for packed
//!   predict encodings without another version bump.
//!
//! `extract_frame` is a pure function over a [`ByteQueue`], so frame
//! reassembly behaves identically whether bytes arrive through the
//! event loop's nonblocking reads or the legacy path's blocking reads —
//! and the slow-sender/oversize policies live in exactly one place.

use std::time::Instant;

use crate::util::bytes::ByteQueue;

/// How request/response bodies are framed on a connection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameDialect {
    /// Newline-delimited JSON (the default; protocol v1 and v2).
    Jsonl,
    /// `u32` little-endian length + encoding tag + payload.
    Bin1,
}

/// Bin1 encoding tag: the payload (after this byte) is UTF-8 JSON.
pub const FRAME_ENC_JSON: u8 = 0x01;

/// Bin1 length-prefix size in bytes.
pub const FRAME_HEADER_BYTES: usize = 4;

/// Outcome of trying to pull one frame off the head of a byte queue.
#[derive(Debug, PartialEq, Eq)]
pub enum Extract {
    /// One complete frame body (trimmed of surrounding whitespace for
    /// Jsonl parity with the legacy `read_line` + `trim` path).  May be
    /// empty (blank line) — callers skip those.
    Frame(String),
    /// Not enough bytes yet; read more.
    Incomplete,
    /// The stream violates the framing contract; answer with this
    /// message and close.  The queue is left as-is — the connection is
    /// done either way.
    Violation(&'static str),
}

/// Pull one frame from `buf` under the `max` body-size bound.
/// Consumes the frame's bytes (header included) on success only.
pub fn extract_frame(dialect: FrameDialect, buf: &mut ByteQueue, max: usize) -> Extract {
    match dialect {
        FrameDialect::Jsonl => match buf.find_byte(b'\n') {
            Some(i) if i > max => Extract::Violation("request line too long"),
            Some(i) => {
                let raw = buf.take(i + 1);
                match String::from_utf8(raw) {
                    Ok(s) => Extract::Frame(s.trim().to_string()),
                    Err(_) => Extract::Violation("request is not valid UTF-8"),
                }
            }
            // No newline yet: a sender that has already streamed more
            // than a full line's bound will never produce a valid frame.
            None if buf.len() > max => Extract::Violation("request line too long"),
            None => Extract::Incomplete,
        },
        FrameDialect::Bin1 => {
            let Some(n) = buf.peek_u32_le() else {
                return Extract::Incomplete;
            };
            let n = n as usize;
            if n == 0 {
                return Extract::Violation("empty frame");
            }
            if n > max {
                return Extract::Violation("frame too large");
            }
            if buf.len() < FRAME_HEADER_BYTES + n {
                return Extract::Incomplete;
            }
            buf.consume(FRAME_HEADER_BYTES);
            let payload = buf.take(n);
            let Some((&tag, body)) = payload.split_first() else {
                return Extract::Violation("empty frame");
            };
            if tag != FRAME_ENC_JSON {
                return Extract::Violation("unknown frame encoding");
            }
            match std::str::from_utf8(body) {
                Ok(s) => Extract::Frame(s.trim().to_string()),
                Err(_) => Extract::Violation("frame is not valid UTF-8"),
            }
        }
    }
}

/// Append one framed payload to `out` in the given dialect.
pub fn encode_frame(dialect: FrameDialect, payload: &str, out: &mut Vec<u8>) {
    match dialect {
        FrameDialect::Jsonl => {
            out.extend_from_slice(payload.as_bytes());
            out.push(b'\n');
        }
        FrameDialect::Bin1 => {
            let n = (payload.len() + 1).min(u32::MAX as usize) as u32;
            out.extend_from_slice(&n.to_le_bytes());
            out.push(FRAME_ENC_JSON);
            out.extend_from_slice(payload.as_bytes());
        }
    }
}

/// What the connection loop should do after writing a response — the
/// third verb (`SwitchDialect`) is why this is no longer a bool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConnDirective {
    /// Keep the connection open in the current dialect.
    Continue,
    /// Write the response, then close.
    Close,
    /// Write the response in the *current* dialect, then speak the new
    /// one for every subsequent frame in both directions.
    SwitchDialect(FrameDialect),
}

/// Per-connection state machine for the event loop: a connection is
/// either assembling a request frame or waiting for the worker pool to
/// finish the one it dispatched (writing happens opportunistically in
/// both states via the write buffer).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConnState {
    /// Assembling the next request frame from read bytes.
    ReadingFrame,
    /// One request is in the worker pool; parsing is paused so at most
    /// one request per connection is in flight (responses stay in
    /// order, and the worker channel is bounded by open connections).
    Dispatched,
}

/// One event-loop connection: socket, buffers, framing state.
pub struct Conn {
    pub stream: std::net::TcpStream,
    pub state: ConnState,
    pub dialect: FrameDialect,
    pub rbuf: ByteQueue,
    pub wbuf: ByteQueue,
    /// Close once `wbuf` drains (violation answered, `shutdown` acked,
    /// or peer EOF seen).
    pub close_after_write: bool,
    /// Peer EOF / hangup observed; no more reads will be attempted.
    pub eof: bool,
    /// When the currently-assembling partial frame started arriving;
    /// `None` while the read buffer holds no partial frame.  The
    /// header-deadline sweep closes connections whose partial is older
    /// than the configured bound (the slow-loris guard).
    pub partial_since: Option<Instant>,
    /// Interest bits currently registered with the poller (tracked so
    /// redundant `modify` calls are elided).  Connections register with
    /// read interest at accept time.
    pub reg_readable: bool,
    pub reg_writable: bool,
}

impl Conn {
    pub fn new(stream: std::net::TcpStream) -> Conn {
        Conn {
            stream,
            state: ConnState::ReadingFrame,
            dialect: FrameDialect::Jsonl,
            rbuf: ByteQueue::new(),
            wbuf: ByteQueue::new(),
            close_after_write: false,
            eof: false,
            partial_since: None,
            reg_readable: true,
            reg_writable: false,
        }
    }

    /// Queue a response payload in the current dialect, then apply the
    /// directive (dialect switches take effect *after* this response).
    pub fn queue_response(&mut self, payload: &str, directive: ConnDirective) {
        let mut bytes = Vec::with_capacity(payload.len() + 8);
        encode_frame(self.dialect, payload, &mut bytes);
        self.wbuf.push(&bytes);
        match directive {
            ConnDirective::Continue => {}
            ConnDirective::Close => self.close_after_write = true,
            ConnDirective::SwitchDialect(d) => self.dialect = d,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(bytes: &[u8]) -> ByteQueue {
        let mut b = ByteQueue::new();
        b.push(bytes);
        b
    }

    const MAX: usize = 64 * 1024;

    #[test]
    fn jsonl_extracts_trimmed_lines_and_leaves_the_rest() {
        let mut b = q(b"  {\"cmd\":\"status\"}\r\n{\"cmd\":");
        assert_eq!(
            extract_frame(FrameDialect::Jsonl, &mut b, MAX),
            Extract::Frame("{\"cmd\":\"status\"}".into())
        );
        // The partial second request stays queued.
        assert_eq!(b.as_slice(), b"{\"cmd\":");
        assert_eq!(extract_frame(FrameDialect::Jsonl, &mut b, MAX), Extract::Incomplete);
    }

    #[test]
    fn jsonl_blank_lines_come_back_as_empty_frames() {
        let mut b = q(b"\n\n{\"cmd\":\"status\"}\n");
        assert_eq!(extract_frame(FrameDialect::Jsonl, &mut b, MAX), Extract::Frame(String::new()));
        assert_eq!(extract_frame(FrameDialect::Jsonl, &mut b, MAX), Extract::Frame(String::new()));
        assert!(matches!(
            extract_frame(FrameDialect::Jsonl, &mut b, MAX),
            Extract::Frame(s) if s == "{\"cmd\":\"status\"}"
        ));
    }

    #[test]
    fn jsonl_oversize_is_a_violation_with_the_pinned_message() {
        // Newline-free overrun: caught as soon as the queue exceeds max.
        let mut b = q(&vec![b'x'; MAX + 1]);
        assert_eq!(
            extract_frame(FrameDialect::Jsonl, &mut b, MAX),
            Extract::Violation("request line too long")
        );
        // A complete line whose body exceeds max is equally rejected.
        let mut with_nl = vec![b'y'; MAX + 1];
        with_nl.push(b'\n');
        let mut b = q(&with_nl);
        assert_eq!(
            extract_frame(FrameDialect::Jsonl, &mut b, MAX),
            Extract::Violation("request line too long")
        );
        // Exactly at the bound still parses.
        let mut at = vec![b'z'; MAX];
        at.push(b'\n');
        let mut b = q(&at);
        assert!(matches!(extract_frame(FrameDialect::Jsonl, &mut b, MAX), Extract::Frame(_)));
    }

    #[test]
    fn bin1_roundtrips_through_encode() {
        let payload = "{\"cmd\":\"predict\",\"arch\":\"cloudlab-v100\"}";
        let mut bytes = Vec::new();
        encode_frame(FrameDialect::Bin1, payload, &mut bytes);
        assert_eq!(bytes.len(), FRAME_HEADER_BYTES + 1 + payload.len());
        let mut b = q(&bytes);
        assert_eq!(
            extract_frame(FrameDialect::Bin1, &mut b, MAX),
            Extract::Frame(payload.to_string())
        );
        assert!(b.is_empty());
    }

    #[test]
    fn bin1_reassembles_across_arbitrary_split_points() {
        let payload = "{\"cmd\":\"status\",\"v\":2}";
        let mut bytes = Vec::new();
        encode_frame(FrameDialect::Bin1, payload, &mut bytes);
        encode_frame(FrameDialect::Bin1, payload, &mut bytes);
        for split in 0..bytes.len() {
            let mut b = ByteQueue::new();
            b.push(bytes.get(..split).unwrap_or(&[]));
            let mut got = Vec::new();
            loop {
                match extract_frame(FrameDialect::Bin1, &mut b, MAX) {
                    Extract::Frame(s) => got.push(s),
                    Extract::Incomplete => break,
                    Extract::Violation(m) => panic!("violation at split {split}: {m}"),
                }
            }
            b.push(bytes.get(split..).unwrap_or(&[]));
            loop {
                match extract_frame(FrameDialect::Bin1, &mut b, MAX) {
                    Extract::Frame(s) => got.push(s),
                    Extract::Incomplete => break,
                    Extract::Violation(m) => panic!("violation at split {split}: {m}"),
                }
            }
            assert_eq!(got, vec![payload.to_string(); 2], "split at {split}");
        }
    }

    #[test]
    fn bin1_rejects_bad_frames() {
        // Oversize length prefix.
        let mut b = ByteQueue::new();
        b.push(&(MAX as u32 + 1).to_le_bytes());
        b.push(&[FRAME_ENC_JSON]);
        assert_eq!(
            extract_frame(FrameDialect::Bin1, &mut b, MAX),
            Extract::Violation("frame too large")
        );
        // Zero-length frame.
        let mut b = q(&0u32.to_le_bytes());
        assert_eq!(
            extract_frame(FrameDialect::Bin1, &mut b, MAX),
            Extract::Violation("empty frame")
        );
        // Unknown encoding tag.
        let mut b = ByteQueue::new();
        b.push(&2u32.to_le_bytes());
        b.push(&[0x7f, b'x']);
        assert_eq!(
            extract_frame(FrameDialect::Bin1, &mut b, MAX),
            Extract::Violation("unknown frame encoding")
        );
        // Invalid UTF-8 body.
        let mut b = ByteQueue::new();
        b.push(&3u32.to_le_bytes());
        b.push(&[FRAME_ENC_JSON, 0xff, 0xfe]);
        assert_eq!(
            extract_frame(FrameDialect::Bin1, &mut b, MAX),
            Extract::Violation("frame is not valid UTF-8")
        );
    }

    #[test]
    fn queue_response_switches_dialect_after_the_ack() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let stream = std::net::TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let mut c = Conn::new(stream);
        c.queue_response(
            "{\"frames\":\"bin1\",\"ok\":true}",
            ConnDirective::SwitchDialect(FrameDialect::Bin1),
        );
        // The ack itself is newline-framed (old dialect) ...
        assert!(c.wbuf.as_slice().ends_with(b"\n"));
        assert_eq!(c.dialect, FrameDialect::Bin1);
        // ... and the next response is binary-framed.
        c.queue_response("{\"ok\":true}", ConnDirective::Continue);
        let tail_len = FRAME_HEADER_BYTES + 1 + "{\"ok\":true}".len();
        let all = c.wbuf.take(usize::MAX);
        let tail = all.get(all.len() - tail_len..).unwrap();
        assert_eq!(&tail[..4], &(1 + "{\"ok\":true}".len() as u32).to_le_bytes());
        assert_eq!(tail[4], FRAME_ENC_JSON);
    }
}
