//! Per-arch energy-table loading with mtime-based hot reload.
//!
//! The serve coordinator keeps one `EnergyTable` per arch in memory and
//! rechecks the backing file's `(mtime, len)` on every lookup: a
//! re-trained table dropped in place is picked up on the next request
//! without restarting the service.  Tables are shared as `Arc`s — the
//! coalescer groups requests by table identity, so all requests answered
//! from one cached instance batch into one predict call.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::SystemTime;

use crate::error::Error;
use crate::model::EnergyTable;
use crate::util::sync::lock_unpoisoned;

struct CacheEntry {
    table: Arc<EnergyTable>,
    mtime: SystemTime,
    len: u64,
}

pub struct TableRegistry {
    /// Directory `<arch>.table.json` files are resolved under.
    dir: PathBuf,
    /// Explicit arch → file overrides (the CLI's `--table FILE`).
    overrides: Mutex<BTreeMap<String, PathBuf>>,
    cache: Mutex<BTreeMap<String, CacheEntry>>,
    reloads: AtomicUsize,
}

impl TableRegistry {
    pub fn new(dir: PathBuf) -> TableRegistry {
        TableRegistry {
            dir,
            overrides: Mutex::new(BTreeMap::new()),
            cache: Mutex::new(BTreeMap::new()),
            reloads: AtomicUsize::new(0),
        }
    }

    /// Map an arch to an explicit table file instead of
    /// `<dir>/<arch>.table.json`.
    pub fn register(&self, arch: &str, path: PathBuf) {
        lock_unpoisoned(&self.overrides).insert(arch.to_string(), path);
    }

    pub fn path_for(&self, arch: &str) -> PathBuf {
        // Poison-tolerant: the registry sits on the request path, and a
        // panic elsewhere must not cascade into every later lookup.
        if let Some(p) = lock_unpoisoned(&self.overrides).get(arch) {
            return p.clone();
        }
        self.dir.join(format!("{arch}.table.json"))
    }

    /// Number of loads from disk so far (first loads + hot reloads).
    pub fn reloads(&self) -> usize {
        self.reloads.load(Ordering::SeqCst)
    }

    /// Fetch the table for an arch, reloading if the file changed since it
    /// was cached.  `(mtime, len)` is the change fingerprint: length
    /// catches rewrites on filesystems with coarse timestamps.
    pub fn get(&self, arch: &str) -> Result<Arc<EnergyTable>, Error> {
        let path = self.path_for(arch);
        // Message shapes preserve the legacy anyhow context chains
        // byte-for-byte — v1 clients have always seen these strings.
        let meta = std::fs::metadata(&path).map_err(|e| {
            Error::TableMissing(format!(
                "no energy table for '{arch}' at {} (train one with `wattchmen train`): {e}",
                path.display()
            ))
        })?;
        let mtime = meta.modified().unwrap_or(SystemTime::UNIX_EPOCH);
        let len = meta.len();
        {
            let cache = lock_unpoisoned(&self.cache);
            if let Some(e) = cache.get(arch) {
                if e.mtime == mtime && e.len == len {
                    return Ok(e.table.clone());
                }
            }
        }
        let table = Arc::new(EnergyTable::load(&path).map_err(|e| {
            Error::TableMissing(format!("loading energy table for '{arch}': {e:#}"))
        })?);
        self.reloads.fetch_add(1, Ordering::SeqCst);
        lock_unpoisoned(&self.cache).insert(
            arch.to_string(),
            CacheEntry {
                table: table.clone(),
                mtime,
                len,
            },
        );
        Ok(table)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table(e_fadd: f64, extra: usize) -> EnergyTable {
        EnergyTable {
            arch: "cloudlab-v100".into(),
            const_power_w: 38.0,
            static_power_w: 44.0,
            entries: std::iter::once(("FADD".to_string(), e_fadd))
                .chain((0..extra).map(|i| (format!("OP{i}"), 1.0)))
                .collect(),
        }
    }

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("wattchmen_registry_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn caches_until_the_file_changes() {
        let dir = temp_dir("reload");
        let path = dir.join("cloudlab-v100.table.json");
        table(1.0, 0).save(&path).unwrap();
        let reg = TableRegistry::new(dir);
        let t1 = reg.get("cloudlab-v100").unwrap();
        let t2 = reg.get("cloudlab-v100").unwrap();
        assert!(Arc::ptr_eq(&t1, &t2), "unchanged file must not reload");
        assert_eq!(reg.reloads(), 1);
        // Rewrite with different content (different length beats coarse
        // mtime granularity deterministically).
        table(2.25, 3).save(&path).unwrap();
        let t3 = reg.get("cloudlab-v100").unwrap();
        assert_eq!(t3.entries["FADD"], 2.25);
        assert_eq!(reg.reloads(), 2);
    }

    #[test]
    fn missing_table_is_a_descriptive_error() {
        let reg = TableRegistry::new(temp_dir("missing"));
        let err = format!("{:#}", reg.get("cloudlab-v100").unwrap_err());
        assert!(err.contains("wattchmen train"), "{err}");
    }

    #[test]
    fn override_wins_over_directory_layout() {
        let dir = temp_dir("override");
        let custom = dir.join("my-table.json");
        table(3.5, 0).save(&custom).unwrap();
        let reg = TableRegistry::new(dir);
        reg.register("summit-v100", custom);
        assert_eq!(reg.get("summit-v100").unwrap().entries["FADD"], 3.5);
    }
}
