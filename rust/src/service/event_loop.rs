//! The readiness-loop acceptor: ONE thread multiplexes every client
//! connection through a [`Poller`] (epoll on Linux, poll(2) fallback),
//! so an idle keep-alive connection costs a registration and two byte
//! queues instead of a parked worker thread.
//!
//! Flow per connection (see [`ConnState`]):
//!
//! ```text
//! readable ─▶ read into rbuf ─▶ extract_frame ─▶ ReadingFrame
//!                                    │ complete frame
//!                                    ▼
//!                          Dispatched (req_tx ─▶ worker pool)
//!                                    │ worker: respond() → done_tx + wake
//!                                    ▼
//!                  queue response in wbuf ─▶ flush (writable events
//!                  drain the remainder) ─▶ back to ReadingFrame
//! ```
//!
//! Read interest is dropped while a request is in flight (at most one
//! per connection), so the worker channel is bounded by the number of
//! open connections and a pipelining client cannot make the loop buffer
//! its backlog.  The workers still run the *same* `respond()` as the
//! legacy thread-per-connection path — admission, deadlines, and the
//! coalescer are untouched; only the transport changed.
//!
//! Slow senders (the bug family this PR retires): a partial frame older
//! than `header_deadline` is answered with an error and closed by the
//! periodic sweep — no thread was ever pinned waiting for its bytes.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::conn::{extract_frame, Conn, ConnDirective, ConnState, Extract};
use super::{accept_backoff, protocol, Shared, MAX_REQUEST_BYTES};
use crate::util::poll::{drain_waker, Event, Interest, Poller};

/// Registration token for the listening socket.
const LISTENER_TOKEN: u64 = 0;
/// Registration token for the waker's read end.
const WAKER_TOKEN: u64 = 1;
/// First token handed to an accepted connection.
const FIRST_CONN_TOKEN: u64 = 2;

/// One complete request frame headed for the worker pool.
pub(crate) struct WorkItem {
    pub token: u64,
    pub line: String,
}

/// A finished response headed back to the event loop.
pub(crate) struct Done {
    pub token: u64,
    pub payload: String,
    pub directive: ConnDirective,
}

/// Poll-timeout ceiling: bounds shutdown and header-deadline-sweep
/// latency.  Completions interrupt the wait through the waker, so this
/// is a ceiling on idle latency, not a response-time cadence.
const TICK: Duration = Duration::from_millis(100);

/// Per-read chunk size; also the slack allowed past `MAX_REQUEST_BYTES`
/// before the loop stops reading a connection that is mid-violation.
const READ_CHUNK: usize = 16 * 1024;

pub(crate) struct EventLoop {
    pub listener: TcpListener,
    pub shared: Arc<Shared>,
    pub poller: Poller,
    pub req_tx: Sender<WorkItem>,
    pub done_rx: Receiver<Done>,
    pub waker_rx: TcpStream,
    /// Bound on partial-frame age (the slow-loris guard); zero disables.
    pub header_deadline: Duration,
}

impl EventLoop {
    /// Drive the loop until shutdown completes.  Consumes self so that
    /// returning drops `req_tx` — the worker pool's receiver
    /// disconnects, workers exit and release their coalescer senders,
    /// and the coordinator drains: the same teardown chain as the
    /// legacy acceptor.
    pub(crate) fn run(mut self) {
        let mut conns: HashMap<u64, Conn> = HashMap::new();
        let mut events: Vec<Event> = Vec::new();
        let mut next_token = FIRST_CONN_TOKEN;
        // Requests currently in the worker pool; shutdown drains them
        // (their responses still go out) before the loop exits.
        let mut dispatched: usize = 0;
        let mut accepting = true;
        // Accept-failure backoff without sleeping the loop: on error the
        // listener is deregistered until the deadline passes.
        let mut accept_paused_until: Option<Instant> = None;
        let mut accept_err_streak: u32 = 0;
        let listener_fd = self.listener.as_raw_fd();
        if self
            .poller
            .register(listener_fd, LISTENER_TOKEN, Interest::READ)
            .is_err()
        {
            return;
        }
        if self
            .poller
            .register(self.waker_rx.as_raw_fd(), WAKER_TOKEN, Interest::READ)
            .is_err()
        {
            return;
        }
        loop {
            if self.poller.wait(&mut events, Some(TICK)).is_err() {
                break;
            }
            for ev in events.iter().copied() {
                match ev.token {
                    LISTENER_TOKEN => {
                        if accepting && accept_paused_until.is_none() {
                            if self.accept_ready(&mut conns, &mut next_token) {
                                accept_err_streak = 0;
                            } else {
                                let _ = self.poller.deregister(listener_fd);
                                accept_paused_until =
                                    Some(Instant::now() + accept_backoff(accept_err_streak));
                                accept_err_streak = accept_err_streak.saturating_add(1);
                            }
                        }
                    }
                    WAKER_TOKEN => drain_waker(&self.waker_rx),
                    token => self.conn_event(&mut conns, token, ev, &mut dispatched),
                }
            }
            events.clear();
            while let Ok(done) = self.done_rx.try_recv() {
                self.complete(&mut conns, done, &mut dispatched);
            }
            self.sweep_header_deadlines(&mut conns);
            if accepting {
                if let Some(until) = accept_paused_until {
                    if Instant::now() >= until
                        && self
                            .poller
                            .register(listener_fd, LISTENER_TOKEN, Interest::READ)
                            .is_ok()
                    {
                        accept_paused_until = None;
                    }
                }
            }
            if self.shared.shutdown.load(Ordering::SeqCst) {
                if accepting {
                    accepting = false;
                    if accept_paused_until.is_none() {
                        let _ = self.poller.deregister(listener_fd);
                    }
                }
                // Idle connections close now; dispatched ones get their
                // response (flushed by the loop) before teardown.
                let idle: Vec<u64> = conns
                    .iter()
                    .filter(|(_, c)| c.state == ConnState::ReadingFrame && c.wbuf.is_empty())
                    .map(|(t, _)| *t)
                    .collect();
                for token in idle {
                    self.close_conn(&mut conns, token);
                }
                if dispatched == 0 && conns.is_empty() {
                    break;
                }
            }
        }
        let remaining: Vec<u64> = conns.keys().copied().collect();
        for token in remaining {
            self.close_conn(&mut conns, token);
        }
    }

    /// Accept until `WouldBlock`.  Returns false on a transient accept
    /// error (e.g. fd exhaustion) — the caller pauses the listener
    /// instead of spinning on a level-triggered readable event.
    fn accept_ready(&mut self, conns: &mut HashMap<u64, Conn>, next_token: &mut u64) -> bool {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let token = *next_token;
                    *next_token += 1;
                    if self
                        .poller
                        .register(stream.as_raw_fd(), token, Interest::READ)
                        .is_err()
                    {
                        self.shared.accept_errors.fetch_add(1, Ordering::SeqCst);
                        continue;
                    }
                    conns.insert(token, Conn::new(stream));
                    self.shared.open_conns.fetch_add(1, Ordering::SeqCst);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return true,
                Err(_) => {
                    self.shared.accept_errors.fetch_add(1, Ordering::SeqCst);
                    return false;
                }
            }
        }
    }

    /// Handle a readiness event for one connection.
    fn conn_event(
        &mut self,
        conns: &mut HashMap<u64, Conn>,
        token: u64,
        ev: Event,
        dispatched: &mut usize,
    ) {
        let close = match conns.get_mut(&token) {
            None => return, // already torn down earlier in this batch
            Some(conn) => {
                if (ev.readable || ev.closed)
                    && !conn.eof
                    && conn.state == ConnState::ReadingFrame
                {
                    read_ready(conn);
                }
                self.advance(token, conn, dispatched)
            }
        };
        if close {
            self.close_conn(conns, token);
        }
    }

    /// Extract-and-dispatch until the connection blocks, then flush.
    /// Returns whether the connection should close now.
    fn advance(&mut self, token: u64, conn: &mut Conn, dispatched: &mut usize) -> bool {
        while conn.state == ConnState::ReadingFrame && !conn.close_after_write {
            match extract_frame(conn.dialect, &mut conn.rbuf, MAX_REQUEST_BYTES) {
                Extract::Frame(line) => {
                    // The deadline clock restarts per frame: leftover
                    // bytes are the *next* request's partial.
                    conn.partial_since = if conn.rbuf.is_empty() {
                        None
                    } else {
                        Some(Instant::now())
                    };
                    if line.is_empty() {
                        continue; // blank keep-alive line
                    }
                    conn.state = ConnState::Dispatched;
                    *dispatched += 1;
                    if self.req_tx.send(WorkItem { token, line }).is_err() {
                        // Worker pool is gone (teardown) — no response
                        // will come back for this request.
                        *dispatched -= 1;
                        conn.close_after_write = true;
                    }
                }
                Extract::Incomplete => {
                    if conn.rbuf.is_empty() {
                        conn.partial_since = None;
                    } else if conn.partial_since.is_none() {
                        conn.partial_since = Some(Instant::now());
                    }
                    break;
                }
                Extract::Violation(msg) => {
                    let payload = protocol::error_json(msg).to_string_compact();
                    conn.queue_response(&payload, ConnDirective::Close);
                    break;
                }
            }
        }
        self.flush(token, conn)
    }

    /// Write as much of `wbuf` as the socket accepts, then reconcile
    /// poller interest.  Returns whether the connection should close.
    fn flush(&mut self, token: u64, conn: &mut Conn) -> bool {
        while !conn.wbuf.is_empty() {
            match (&conn.stream).write(conn.wbuf.as_slice()) {
                Ok(0) => return true,
                Ok(n) => conn.wbuf.consume(n),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return true,
            }
        }
        if conn.wbuf.is_empty() {
            if conn.close_after_write {
                return true;
            }
            if conn.eof && conn.state == ConnState::ReadingFrame {
                // Peer finished sending and nothing is owed.  (An
                // in-flight request still gets its response first.)
                return true;
            }
        }
        self.update_interest(token, conn);
        false
    }

    /// Keep the poller registration in sync with what the connection
    /// can actually make progress on.
    fn update_interest(&mut self, token: u64, conn: &mut Conn) {
        let want = Interest {
            readable: conn.state == ConnState::ReadingFrame && !conn.close_after_write && !conn.eof,
            writable: !conn.wbuf.is_empty(),
        };
        if want.readable != conn.reg_readable || want.writable != conn.reg_writable {
            if self.poller.modify(conn.stream.as_raw_fd(), token, want).is_ok() {
                conn.reg_readable = want.readable;
                conn.reg_writable = want.writable;
            }
        }
    }

    /// Deliver a worker's response and resume reading (pipelined
    /// requests already buffered are dispatched immediately).
    fn complete(&mut self, conns: &mut HashMap<u64, Conn>, done: Done, dispatched: &mut usize) {
        *dispatched = dispatched.saturating_sub(1);
        let close = match conns.get_mut(&done.token) {
            None => return, // connection tore down while its request ran
            Some(conn) => {
                conn.queue_response(&done.payload, done.directive);
                conn.state = ConnState::ReadingFrame;
                self.advance(done.token, conn, dispatched)
            }
        };
        if close {
            self.close_conn(conns, done.token);
        }
    }

    /// Close connections whose partial frame is older than the header
    /// deadline — the slow-loris guard.  Runs every tick; cost is one
    /// `Instant` comparison per connection holding a partial.
    fn sweep_header_deadlines(&mut self, conns: &mut HashMap<u64, Conn>) {
        if self.header_deadline.is_zero() {
            return;
        }
        let expired: Vec<u64> = conns
            .iter()
            .filter(|(_, c)| {
                !c.close_after_write
                    && c.partial_since
                        .map_or(false, |t| t.elapsed() > self.header_deadline)
            })
            .map(|(t, _)| *t)
            .collect();
        for token in expired {
            let close = {
                let Some(conn) = conns.get_mut(&token) else { continue };
                self.shared.slow_client_closes.fetch_add(1, Ordering::SeqCst);
                let payload =
                    protocol::error_json("request header deadline exceeded").to_string_compact();
                conn.queue_response(&payload, ConnDirective::Close);
                self.flush(token, conn)
            };
            if close {
                self.close_conn(conns, token);
            }
        }
    }

    fn close_conn(&mut self, conns: &mut HashMap<u64, Conn>, token: u64) {
        if let Some(conn) = conns.remove(&token) {
            let _ = self.poller.deregister(conn.stream.as_raw_fd());
            let _ = conn.stream.shutdown(Shutdown::Both);
            self.shared.open_conns.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

/// Drain the socket into the read buffer until it blocks, EOF, or the
/// buffer passes the request bound (the framing layer then answers with
/// the oversize violation — reading further would buffer an attacker's
/// stream without limit).
fn read_ready(conn: &mut Conn) {
    let mut chunk = [0u8; READ_CHUNK];
    loop {
        match (&conn.stream).read(&mut chunk) {
            Ok(0) => {
                conn.eof = true;
                return;
            }
            Ok(n) => {
                conn.rbuf.push(chunk.get(..n).unwrap_or(&[]));
                if conn.rbuf.len() > MAX_REQUEST_BYTES + READ_CHUNK {
                    return;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => {
                conn.eof = true;
                return;
            }
        }
    }
}
