//! Memoized application profiling for the prediction service.
//!
//! `profile_app` is pure static analysis, so its result for a given
//! (arch, workload, scaling duration) never changes — the service profiles
//! each combination once and answers every later request from the cache.
//! Profiles are `Arc`-shared straight into the coalescer's batches, so a
//! 64-request burst over 16 workloads profiles at most 16 times and clones
//! nothing.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::Error;
use crate::gpusim::config::ArchConfig;
use crate::gpusim::profiler::{profile_app, KernelProfile};
use crate::report::scaled_workload;
use crate::util::sync::lock_unpoisoned;
use crate::workloads::{self, Workload};

type Key = (String, String, u64);

/// Retention bound: `duration_s` is client-controlled, so an adversarial
/// (or merely chatty) client could otherwise grow the cache without
/// limit.  When full the cache is cleared — steady-state serving uses a
/// handful of (arch, workload, duration) triples, so a wipe is rare and
/// repopulates in one burst.
const MAX_ENTRIES: usize = 1024;

pub struct ProfileCache {
    cache: Mutex<BTreeMap<Key, Arc<Vec<KernelProfile>>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl ProfileCache {
    pub fn new() -> ProfileCache {
        ProfileCache {
            cache: Mutex::new(BTreeMap::new()),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
        }
    }

    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::SeqCst)
    }

    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::SeqCst)
    }

    /// Profiles for `workload` scaled to `duration_s` on `cfg`, memoized.
    /// The pipeline (evaluation suite lookup → `scaled_workload` →
    /// `profile_app`) is exactly the CLI's, so served predictions match
    /// `wattchmen predict` byte for byte.
    pub fn get(
        &self,
        cfg: &ArchConfig,
        workload: &str,
        duration_s: f64,
    ) -> Result<Arc<Vec<KernelProfile>>, Error> {
        let key = (
            cfg.name.clone(),
            workload.to_string(),
            duration_s.to_bits(),
        );
        // Poison-tolerant locks throughout: the cache is on the request
        // path, and a panicking worker elsewhere must not turn every
        // later request into a poison-panic.
        if let Some(p) = lock_unpoisoned(&self.cache).get(&key) {
            self.hits.fetch_add(1, Ordering::SeqCst);
            return Ok(p.clone());
        }
        let w = workloads::evaluation_suite(cfg.gen)
            .into_iter()
            .find(|w| w.name == workload)
            .ok_or_else(|| Error::unknown_workload(workload, &cfg.name))?;
        let scaled = scaled_workload(cfg, &w, duration_s);
        Ok(self.get_for(cfg, &scaled, duration_s))
    }

    /// Profiles of an already-scaled workload, memoized under the same
    /// (arch, workload, duration) key — [`get`](Self::get)'s slow path,
    /// and the entry point for callers that already hold a scaled
    /// workload ([`Engine::profiles`](crate::engine::Engine::profiles)).
    /// A miss is counted only here, i.e. only for requests whose
    /// profiling actually ran (the soak tests use the miss counter as a
    /// deterministic admission barrier).
    pub fn get_for(
        &self,
        cfg: &ArchConfig,
        scaled: &Workload,
        duration_s: f64,
    ) -> Arc<Vec<KernelProfile>> {
        let key = (
            cfg.name.clone(),
            scaled.name.clone(),
            duration_s.to_bits(),
        );
        if let Some(p) = lock_unpoisoned(&self.cache).get(&key) {
            self.hits.fetch_add(1, Ordering::SeqCst);
            return p.clone();
        }
        self.misses.fetch_add(1, Ordering::SeqCst);
        let profiles = Arc::new(profile_app(cfg, &scaled.kernels));
        // A concurrent miss may have raced us here; either instance is
        // identical, last insert wins.
        let mut cache = lock_unpoisoned(&self.cache);
        if cache.len() >= MAX_ENTRIES {
            cache.clear();
        }
        cache.insert(key, profiles.clone());
        profiles
    }
}

impl Default for ProfileCache {
    fn default() -> ProfileCache {
        ProfileCache::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memoizes_per_arch_workload_duration() {
        let cache = ProfileCache::new();
        let cfg = ArchConfig::cloudlab_v100();
        let a = cache.get(&cfg, "hotspot", 90.0).unwrap();
        let b = cache.get(&cfg, "hotspot", 90.0).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        // A different duration is a different profile (different iters).
        let c = cache.get(&cfg, "hotspot", 45.0).unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!((cache.hits(), cache.misses()), (1, 2));
        assert!(a[0].duration_s > c[0].duration_s);
    }

    #[test]
    fn unknown_workload_is_an_error() {
        let cache = ProfileCache::new();
        let cfg = ArchConfig::cloudlab_v100();
        let err = cache.get(&cfg, "nosuch", 90.0).unwrap_err().to_string();
        assert!(err.contains("unknown workload"), "{err}");
        // kmeans exists on V100 but not on A100 (CUDA 12 dropped it).
        let a100 = ArchConfig::by_name("lonestar-a100").unwrap();
        assert!(cache.get(&a100, "kmeans", 90.0).is_err());
    }
}
