//! `wattchmen serve` — the batched multi-table prediction service.
//!
//! A std-only JSON-over-TCP server (tokio is unavailable offline — same
//! constraint DESIGN.md applied to `cluster/`) that turns the per-table
//! prediction pipeline into an online service:
//!
//! * acceptor thread — hands sockets to the worker pool;
//! * worker pool — parses newline-delimited JSON requests, resolves
//!   tables through [`TableRegistry`] (mtime-based hot reload) and
//!   profiles through [`ProfileCache`] (memoized `profile_app`), then
//!   enqueues [`PredictJob`]s and blocks on their replies;
//! * coordinator — [`PredictServer::run`] drives the request
//!   [`Coalescer`] on the *calling* thread, where the non-Sync PJRT
//!   artifacts may live; concurrent requests against the same table
//!   batch into single `model::predict_many` calls.
//!
//! Every layer shares the CLI's exact pipeline (suite lookup →
//! `scaled_workload` → `profile_app` → `predict_many` → `render_line`),
//! so a served prediction is byte-identical to `wattchmen predict`.

pub mod cache;
pub mod coalescer;
pub mod protocol;
pub mod registry;

pub use cache::ProfileCache;
pub use coalescer::{submit_and_wait, Coalescer, Job, PredictJob};
pub use registry::TableRegistry;

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::gpusim::config::ArchConfig;
use crate::model::{Mode, Prediction};
use crate::report::context::WORKLOAD_SECS;
use crate::runtime::Artifacts;
use crate::util::json::Json;

use protocol::Request;

/// Server configuration (all CLI-settable; see `wattchmen serve`).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address; port 0 picks an ephemeral port (tests, demo).
    pub addr: String,
    /// Worker threads.  Workers block while their batch lingers in the
    /// coalescer, so the pool must cover the expected concurrent burst
    /// (default 64 — two full predict-artifact chunks).
    pub workers: usize,
    /// How long the coalescer holds a batch open for more requests.
    pub linger: Duration,
    /// Directory `TableRegistry` resolves `<arch>.table.json` under.
    pub tables_dir: PathBuf,
    /// Workload scaling target used when a request omits `duration_s`
    /// (the CLI's measurement protocol, for byte-identical parity).
    pub default_duration_s: f64,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:0".into(),
            workers: 64,
            linger: Duration::from_millis(10),
            tables_dir: PathBuf::from("."),
            default_duration_s: WORKLOAD_SECS,
        }
    }
}

/// State shared by the worker pool and the coordinator.
struct Shared {
    addr: SocketAddr,
    registry: TableRegistry,
    profiles: ProfileCache,
    coalescer: Coalescer,
    shutdown: AtomicBool,
    served: AtomicUsize,
    default_duration_s: f64,
}

pub struct PredictServer {
    shared: Arc<Shared>,
    handles: Mutex<Vec<thread::JoinHandle<()>>>,
}

impl PredictServer {
    /// Bind the listener and spawn the acceptor + worker pool.  Call
    /// [`run`](Self::run) (blocking) to start answering predictions.
    pub fn bind(cfg: ServeConfig) -> Result<PredictServer> {
        let listener =
            TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        let (coalescer, jobs_tx) = Coalescer::new(cfg.linger);
        let shared = Arc::new(Shared {
            addr,
            registry: TableRegistry::new(cfg.tables_dir),
            profiles: ProfileCache::new(),
            coalescer,
            shutdown: AtomicBool::new(false),
            served: AtomicUsize::new(0),
            default_duration_s: cfg.default_duration_s,
        });

        let (conn_tx, conn_rx) = mpsc::channel::<TcpStream>();
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let mut handles = Vec::with_capacity(cfg.workers + 1);
        for _ in 0..cfg.workers.max(1) {
            let shared = shared.clone();
            let conn_rx = conn_rx.clone();
            let jobs_tx = jobs_tx.clone();
            handles.push(thread::spawn(move || loop {
                let conn = conn_rx.lock().unwrap().recv();
                let Ok(stream) = conn else { break };
                let _ = handle_conn(stream, &shared, &jobs_tx);
            }));
        }
        // jobs_tx's original drops here: once the acceptor exits and the
        // workers drain, the coalescer's receiver disconnects and run()
        // returns — that IS clean shutdown.
        // Non-blocking accept loop so the acceptor can observe the
        // shutdown flag regardless of bind address or platform (a
        // wake-by-self-connect would not reach e.g. an 0.0.0.0 bind
        // everywhere).
        listener.set_nonblocking(true)?;
        {
            let shared = shared.clone();
            handles.push(thread::spawn(move || loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        // Accepted sockets must not inherit non-blocking
                        // mode (platform-dependent otherwise).
                        if stream.set_nonblocking(false).is_err() {
                            continue;
                        }
                        if conn_tx.send(stream).is_err() {
                            break;
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        thread::sleep(Duration::from_millis(20));
                    }
                    Err(_) => thread::sleep(Duration::from_millis(20)),
                }
            }));
        }
        Ok(PredictServer {
            shared,
            handles: Mutex::new(handles),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.shared.addr
    }

    pub fn registry(&self) -> &TableRegistry {
        &self.shared.registry
    }

    pub fn profile_cache(&self) -> &ProfileCache {
        &self.shared.profiles
    }

    /// Batched predict calls issued so far (the coalescing counter).
    pub fn batch_calls(&self) -> usize {
        self.shared.coalescer.batch_calls()
    }

    /// Predict requests answered successfully so far.
    pub fn served(&self) -> usize {
        self.shared.served.load(Ordering::SeqCst)
    }

    /// Answer requests until a `shutdown` request arrives, then join every
    /// thread.  Runs the coalescer on the calling thread — the PJRT
    /// artifacts are not Sync, so they stay with the coordinator (the same
    /// design as the cluster campaign).
    pub fn run(&self, arts: Option<&Artifacts>) -> Result<()> {
        self.shared.coalescer.run(arts);
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
        Ok(())
    }
}

/// Largest accepted request line; a predict request is <200 bytes, so
/// 64 KiB is generous while bounding per-connection memory.
const MAX_REQUEST_BYTES: usize = 64 * 1024;

fn handle_conn(
    stream: TcpStream,
    shared: &Shared,
    jobs: &Sender<Job>,
) -> std::io::Result<()> {
    // Periodic read timeouts let idle keep-alive connections notice
    // shutdown instead of pinning their worker forever.
    stream.set_read_timeout(Some(Duration::from_millis(250)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    let mut line = String::new();
    loop {
        // Byte-budgeted read: each call may append at most what is left
        // of the request bound, so a client streaming newline-free bytes
        // can never grow the buffer past MAX_REQUEST_BYTES + 1.
        if line.len() > MAX_REQUEST_BYTES {
            let err = protocol::error_json("request line too long");
            writer.write_all(err.to_string_compact().as_bytes())?;
            writer.write_all(b"\n")?;
            writer.flush()?;
            break;
        }
        let budget = (MAX_REQUEST_BYTES + 1 - line.len()) as u64;
        match std::io::Read::by_ref(&mut reader).take(budget).read_line(&mut line) {
            Ok(0) => break, // EOF (budget is always ≥ 1 here)
            Ok(_) => {
                if !line.ends_with('\n') {
                    // Mid-line: budget cap hit or sender paused — keep
                    // accumulating (the bound above catches overruns).
                    continue;
                }
                let request = line.trim().to_string();
                line.clear();
                if request.is_empty() {
                    continue;
                }
                let (response, done) = respond(&request, shared, jobs);
                writer.write_all(response.to_string_compact().as_bytes())?;
                writer.write_all(b"\n")?;
                writer.flush()?;
                if done {
                    break;
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Partial bytes (if any) stay accumulated in `line`.
                if shared.shutdown.load(Ordering::SeqCst) {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    Ok(())
}

/// Build the response for one request line; the bool asks the connection
/// loop to close afterwards.
fn respond(request: &str, shared: &Shared, jobs: &Sender<Job>) -> (Json, bool) {
    match protocol::parse_request(request) {
        Err(e) => (protocol::error_json(&e), false),
        Ok(Request::Status) => (status_json(shared), false),
        Ok(Request::Metrics) => (
            protocol::metrics_json(&protocol::prometheus_text(&counters(shared))),
            false,
        ),
        Ok(Request::Shutdown) => {
            // The acceptor polls this flag (non-blocking accept loop) and
            // idle connections see it via their read timeouts.
            shared.shutdown.store(true, Ordering::SeqCst);
            (protocol::ack_json("shutting down"), true)
        }
        Ok(Request::Predict {
            arch,
            workload,
            mode,
            duration_s,
        }) => {
            let secs = duration_s.unwrap_or(shared.default_duration_s);
            match serve_predict(shared, jobs, &arch, &workload, mode, secs) {
                Ok(pred) => {
                    shared.served.fetch_add(1, Ordering::SeqCst);
                    (protocol::prediction_json(&pred), false)
                }
                Err(e) => (protocol::error_json(&e), false),
            }
        }
    }
}

fn serve_predict(
    shared: &Shared,
    jobs: &Sender<Job>,
    arch: &str,
    workload: &str,
    mode: Mode,
    duration_s: f64,
) -> Result<Prediction, String> {
    let cfg = ArchConfig::by_name(arch)
        .ok_or_else(|| format!("unknown arch '{arch}' (see `wattchmen list`)"))?;
    let table = shared.registry.get(arch).map_err(|e| format!("{e:#}"))?;
    let profiles = shared
        .profiles
        .get(&cfg, workload, duration_s)
        .map_err(|e| format!("{e:#}"))?;
    submit_and_wait(jobs, table, workload.to_string(), profiles, mode)
}

/// Snapshot of the service counters (shared by `status` and `metrics`).
fn counters(shared: &Shared) -> protocol::ServiceCounters {
    protocol::ServiceCounters {
        served: shared.served.load(Ordering::SeqCst),
        batched_predict_calls: shared.coalescer.batch_calls(),
        table_reloads: shared.registry.reloads(),
        profile_cache_hits: shared.profiles.hits(),
        profile_cache_misses: shared.profiles.misses(),
    }
}

fn status_json(shared: &Shared) -> Json {
    let c = counters(shared);
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("served", Json::Num(c.served as f64)),
        (
            "batched_predict_calls",
            Json::Num(c.batched_predict_calls as f64),
        ),
        ("table_reloads", Json::Num(c.table_reloads as f64)),
        ("profile_cache_hits", Json::Num(c.profile_cache_hits as f64)),
        (
            "profile_cache_misses",
            Json::Num(c.profile_cache_misses as f64),
        ),
    ])
}
