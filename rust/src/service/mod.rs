//! `wattchmen serve` — the batched multi-table prediction service.
//!
//! A std-only TCP server (tokio is unavailable offline — the same
//! constraint that keeps `cluster/` on `std::thread`) that turns the
//! per-table prediction pipeline into an online service.  Two acceptor
//! architectures share every other layer (see [`Acceptor`], SERVE.md):
//!
//! * **event loop** (default on unix) — ONE thread multiplexes every
//!   connection through `util::poll` (epoll/poll(2)); complete request
//!   frames are dispatched to the worker pool, so thousands of idle
//!   keep-alive connections cost registrations, not threads
//!   ([`event_loop`]);
//! * **thread-per-connection** (`Acceptor::ThreadPerConn`, and the
//!   non-unix fallback) — the legacy path: an acceptor thread hands
//!   sockets to workers that own them for the connection's lifetime;
//! * worker pool — parses requests (protocol v1 or v2 over either
//!   frame dialect, see [`protocol`] and [`conn`]), resolves tables
//!   through [`TableRegistry`] (mtime-based hot reload), and answers
//!   each predict-family request through a per-request
//!   [`Engine`](crate::engine::Engine) handle — the same typed facade
//!   the CLI and the report pipeline use — which memoizes profiles in
//!   the counter-instrumented [`ProfileCache`], enqueues
//!   [`PredictJob`](crate::runtime::coalescer::PredictJob)s, and blocks
//!   on their replies;
//! * coordinator — [`PredictServer::run`] drives the request
//!   [`Coalescer`] on the *calling* thread, where the non-Sync PJRT
//!   artifacts may live; concurrent requests against the same table
//!   batch into single `model::predict_many` calls.
//!
//! Because every surface routes through the engine, a served prediction
//! is byte-identical to `wattchmen predict` by construction.
//!
//! Overload safety (see `protocol` for the wire shapes): admission to
//! the coalescer queue is bounded by a [`Semaphore`] — a request that
//! finds the queue full is shed immediately with an `overloaded`
//! response instead of growing the queue without bound — and every
//! predict-family request carries an optional deadline budget enforced
//! on both sides of the queue (the waiting worker *and* the
//! coordinator), so a slow batch or a pinned coordinator cannot hang a
//! request past its budget.  Every predict-family request that parses
//! lands in exactly one of `served` / `rejected` / `deadline_exceeded` /
//! `request_errors` (malformed lines are answered with an error and
//! counted by none — they never reach admission).  Failures are typed
//! [`crate::Error`]s end to end: the counter classification and the wire
//! rendering (v1 legacy strings, v2 structured codes) both key off the
//! same value.

pub mod cache;
pub mod conn;
#[cfg(unix)]
pub(crate) mod event_loop;
pub mod protocol;
pub mod registry;

pub use cache::ProfileCache;
pub use registry::TableRegistry;

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::engine::{Engine, PredictRequest, SweepRequest};
use crate::error::Error;
use crate::gpusim::config::ArchConfig;
use crate::model::Prediction;
use crate::report::cache::EvalCache;
use crate::report::context::WORKLOAD_SECS;
use crate::runtime::coalescer::{Coalescer, Job};
use crate::runtime::Artifacts;
use crate::util::bytes::ByteQueue;
use crate::util::json::Json;
use crate::util::sync::{lock_unpoisoned, Backoff, Semaphore};

use conn::{extract_frame, ConnDirective, Extract, FrameDialect};
use protocol::{Proto, Request};

/// Which acceptor architecture [`PredictServer::bind`] spawns.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Acceptor {
    /// One readiness-loop thread multiplexing every connection
    /// (`util::poll` + [`event_loop`]); requests run on the worker
    /// pool.  The default on unix.
    EventLoop,
    /// The legacy architecture: each accepted socket occupies one
    /// worker thread for the connection's lifetime.  The only option
    /// off unix, and the fallback if `EventLoop` is requested there.
    ThreadPerConn,
}

impl Default for Acceptor {
    fn default() -> Acceptor {
        if cfg!(unix) {
            Acceptor::EventLoop
        } else {
            Acceptor::ThreadPerConn
        }
    }
}

/// Server configuration (all CLI-settable; see `wattchmen serve`).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address; port 0 picks an ephemeral port (tests, demo).
    pub addr: String,
    /// Worker threads.  Workers block while their batch lingers in the
    /// coalescer, so the pool must cover the expected concurrent burst
    /// (default 64 — two full predict-artifact chunks).
    pub workers: usize,
    /// How long the coalescer holds a batch open for more requests.
    pub linger: Duration,
    /// Directory `TableRegistry` resolves `<arch>.table.json` under.
    pub tables_dir: PathBuf,
    /// Workload scaling target used when a request omits `duration_s`
    /// (the CLI's measurement protocol, for byte-identical parity).
    pub default_duration_s: f64,
    /// Bound on admitted predict-family requests whose jobs have not yet
    /// been consumed by the coordinator (the permit rides inside the
    /// queued job, so even a request whose waiter timed out keeps its
    /// slot until the job leaves the queue); clamped to ≥ 1.  Excess
    /// requests are shed with an `overloaded` response.
    pub queue_capacity: usize,
    /// Server-wide deadline budget per predict-family request; `None`
    /// disables.  A request's `deadline_ms` field may only *tighten* it
    /// (the effective budget is the minimum of the two) — a client must
    /// not be able to hold a queue slot past the operator's ceiling.
    pub deadline: Option<Duration>,
    /// Acceptor architecture (event loop on unix by default).
    pub acceptor: Acceptor,
    /// Bound on how long a connection may take to assemble one complete
    /// request frame (the slow-loris guard, enforced by BOTH acceptor
    /// paths).  Zero disables.  Generous by default: it exists to stop
    /// a sender that never finishes, not to race legitimate clients.
    pub header_deadline: Duration,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:0".into(),
            workers: 64,
            linger: Duration::from_millis(10),
            tables_dir: PathBuf::from("."),
            default_duration_s: WORKLOAD_SECS,
            queue_capacity: 256,
            deadline: None,
            acceptor: Acceptor::default(),
            header_deadline: Duration::from_secs(10),
        }
    }
}

/// State shared by the worker pool and the coordinator.
struct Shared {
    addr: SocketAddr,
    registry: TableRegistry,
    profiles: Arc<ProfileCache>,
    /// Shared by every per-request [`Engine`] handle (the serve predict
    /// path never touches it; constructing one per request is what the
    /// shared instance avoids).
    eval_cache: Arc<EvalCache>,
    coalescer: Coalescer,
    /// Admission bound over the coalescer queue: a permit is taken at
    /// admission, rides inside the [`PredictJob`], and is released when
    /// the coordinator consumes the job (executed or shed).
    queue: Arc<Semaphore>,
    /// Embedder-facing clone of the coalescer's job sender (for
    /// [`PredictServer::coordinator_handle`]); dropped at shutdown so
    /// the coalescer can drain.
    jobs_tx: Mutex<Option<Sender<Job>>>,
    shutdown: AtomicBool,
    served: AtomicUsize,
    rejected: AtomicUsize,
    deadline_exceeded: AtomicUsize,
    request_errors: AtomicUsize,
    /// Listener `accept()` failures (fd exhaustion, aborted handshakes
    /// surfaced as errors, …).  The acceptor counts them and backs off
    /// instead of spinning — see [`accept_backoff`].
    accept_errors: AtomicUsize,
    /// Currently-open connections (event-loop acceptor only; a gauge).
    open_conns: AtomicUsize,
    /// Connections closed by the header deadline (slow-loris guard).
    slow_client_closes: AtomicUsize,
    /// Connections upgraded to the bin1 frame dialect.
    frame_upgrades: AtomicUsize,
    default_duration_s: f64,
    default_deadline: Option<Duration>,
    /// Bound on partial-frame assembly time; zero disables.
    header_deadline: Duration,
    /// The coalescer linger window in ms, stored atomically so the
    /// `overloaded` retry hint (one batch's worth of drain time) is
    /// derived at *response* time — a hot-reloaded linger must not ship
    /// a stale hint (the bug this field replaces: the hint used to be
    /// computed once at construction).
    linger_ms: AtomicU64,
}

impl Shared {
    /// The `retry_after_ms` hint for `overloaded` responses, derived
    /// from the *current* linger window on every call.
    fn retry_after_ms(&self) -> u64 {
        self.linger_ms.load(Ordering::SeqCst).max(1)
    }
}

pub struct PredictServer {
    shared: Arc<Shared>,
    handles: Mutex<Vec<thread::JoinHandle<()>>>,
}

impl PredictServer {
    /// Bind the listener and spawn the acceptor + worker pool.  Call
    /// [`run`](Self::run) (blocking) to start answering predictions.
    pub fn bind(cfg: ServeConfig) -> Result<PredictServer, Error> {
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| Error::io(format!("binding {}: {e}", cfg.addr)))?;
        let addr = listener.local_addr()?;
        let (coalescer, jobs_tx) = Coalescer::new(cfg.linger);
        let shared = Arc::new(Shared {
            addr,
            registry: TableRegistry::new(cfg.tables_dir),
            profiles: Arc::new(ProfileCache::new()),
            eval_cache: Arc::new(EvalCache::new()),
            coalescer,
            queue: Arc::new(Semaphore::new(cfg.queue_capacity)),
            jobs_tx: Mutex::new(Some(jobs_tx.clone())),
            shutdown: AtomicBool::new(false),
            served: AtomicUsize::new(0),
            rejected: AtomicUsize::new(0),
            deadline_exceeded: AtomicUsize::new(0),
            request_errors: AtomicUsize::new(0),
            accept_errors: AtomicUsize::new(0),
            open_conns: AtomicUsize::new(0),
            slow_client_closes: AtomicUsize::new(0),
            frame_upgrades: AtomicUsize::new(0),
            default_duration_s: cfg.default_duration_s,
            default_deadline: cfg.deadline,
            header_deadline: cfg.header_deadline,
            linger_ms: AtomicU64::new(cfg.linger.as_millis().max(1) as u64),
        });
        listener.set_nonblocking(true)?;

        #[cfg(unix)]
        if cfg.acceptor == Acceptor::EventLoop {
            return Self::bind_event_loop(cfg, listener, shared, jobs_tx);
        }
        Self::bind_thread_per_conn(cfg, listener, shared, jobs_tx)
    }

    /// The readiness-loop acceptor: workers consume complete request
    /// frames instead of owning sockets; one thread owns every fd.
    #[cfg(unix)]
    fn bind_event_loop(
        cfg: ServeConfig,
        listener: TcpListener,
        shared: Arc<Shared>,
        jobs_tx: Sender<Job>,
    ) -> Result<PredictServer, Error> {
        use crate::util::poll::{Poller, Waker};
        use event_loop::{Done, EventLoop, WorkItem};

        let poller = Poller::new().map_err(|e| Error::io(format!("creating poller: {e}")))?;
        let (waker, waker_rx) =
            Waker::pair().map_err(|e| Error::io(format!("creating waker: {e}")))?;
        let (req_tx, req_rx) = mpsc::channel::<WorkItem>();
        let (done_tx, done_rx) = mpsc::channel::<Done>();
        let req_rx = Arc::new(Mutex::new(req_rx));
        let mut handles = Vec::with_capacity(cfg.workers + 1);
        for _ in 0..cfg.workers.max(1) {
            let shared = shared.clone();
            let req_rx = req_rx.clone();
            let jobs_tx = jobs_tx.clone();
            let done_tx = done_tx.clone();
            let waker = waker.clone();
            handles.push(thread::spawn(move || loop {
                let item = lock_unpoisoned(&req_rx).recv();
                let Ok(WorkItem { token, line }) = item else { break };
                let (response, directive) = respond(&line, &shared, &jobs_tx);
                let done = Done {
                    token,
                    payload: response.to_string_compact(),
                    directive,
                };
                if done_tx.send(done).is_err() {
                    break;
                }
                waker.wake();
            }));
        }
        // done_tx's original drops here: once the event loop exits and
        // workers drain, the done channel fully disconnects.  jobs_tx's
        // original drops at end of scope; the surviving clones are the
        // workers' and the Shared slot (taken by the shutdown request),
        // after which the coalescer's receiver disconnects and run()
        // returns — that IS clean shutdown.
        drop(done_tx);
        let ev = EventLoop {
            listener,
            shared: shared.clone(),
            poller,
            req_tx,
            done_rx,
            waker_rx,
            header_deadline: cfg.header_deadline,
        };
        handles.push(thread::spawn(move || ev.run()));
        Ok(PredictServer {
            shared,
            handles: Mutex::new(handles),
        })
    }

    /// The legacy thread-per-connection acceptor (and the non-unix
    /// fallback): each accepted socket occupies one worker until it
    /// closes.  Shares the framing layer (`conn`) and `respond()` with
    /// the event loop, so dialects and deadlines behave identically.
    fn bind_thread_per_conn(
        cfg: ServeConfig,
        listener: TcpListener,
        shared: Arc<Shared>,
        jobs_tx: Sender<Job>,
    ) -> Result<PredictServer, Error> {
        let (conn_tx, conn_rx) = mpsc::channel::<TcpStream>();
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let mut handles = Vec::with_capacity(cfg.workers + 1);
        for _ in 0..cfg.workers.max(1) {
            let shared = shared.clone();
            let conn_rx = conn_rx.clone();
            let jobs_tx = jobs_tx.clone();
            handles.push(thread::spawn(move || loop {
                let conn = lock_unpoisoned(&conn_rx).recv();
                let Ok(stream) = conn else { break };
                let _ = handle_conn(stream, &shared, &jobs_tx);
            }));
        }
        // jobs_tx's original drops here; the surviving clones are the
        // workers' (dropped as they drain after the acceptor exits) and
        // the Shared slot (taken by the shutdown request), after which
        // the coalescer's receiver disconnects and run() returns — that
        // IS clean shutdown.
        // The listener is already non-blocking (set in bind()) so the
        // acceptor can observe the shutdown flag regardless of bind
        // address or platform (a wake-by-self-connect would not reach
        // e.g. an 0.0.0.0 bind everywhere).
        {
            let shared = shared.clone();
            handles.push(thread::spawn(move || {
                // Consecutive accept() failures (WouldBlock excluded):
                // each one counts toward `accept_errors` and stretches
                // the retry delay; any successful accept resets it.
                let mut err_streak: u32 = 0;
                loop {
                    if shared.shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            err_streak = 0;
                            // Accepted sockets must not inherit non-blocking
                            // mode (platform-dependent otherwise).
                            if stream.set_nonblocking(false).is_err() {
                                continue;
                            }
                            if conn_tx.send(stream).is_err() {
                                break;
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            err_streak = 0;
                            thread::sleep(Duration::from_millis(20));
                        }
                        Err(_) => {
                            // Transient resource failure (e.g. EMFILE).
                            // Count it and back off: spinning on a hot
                            // error starves the workers of the fds they
                            // need to clear the very condition.
                            shared.accept_errors.fetch_add(1, Ordering::SeqCst);
                            thread::sleep(accept_backoff(err_streak));
                            err_streak = err_streak.saturating_add(1);
                        }
                    }
                }
            }));
        }
        Ok(PredictServer {
            shared,
            handles: Mutex::new(handles),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.shared.addr
    }

    pub fn registry(&self) -> &TableRegistry {
        &self.shared.registry
    }

    pub fn profile_cache(&self) -> &ProfileCache {
        &self.shared.profiles
    }

    /// Batched predict calls issued so far (the coalescing counter).
    pub fn batch_calls(&self) -> usize {
        self.shared.coalescer.batch_calls()
    }

    /// Predict requests answered successfully so far.
    pub fn served(&self) -> usize {
        self.shared.served.load(Ordering::SeqCst)
    }

    /// Requests shed with an `overloaded` response (queue full).
    pub fn rejected(&self) -> usize {
        self.shared.rejected.load(Ordering::SeqCst)
    }

    /// Requests that missed their deadline budget.
    pub fn deadline_exceeded(&self) -> usize {
        self.shared.deadline_exceeded.load(Ordering::SeqCst)
    }

    /// Requests answered with a non-deadline, non-overload error.
    pub fn request_errors(&self) -> usize {
        self.shared.request_errors.load(Ordering::SeqCst)
    }

    /// Listener accept() failures survived so far (backed off, retried).
    pub fn accept_errors(&self) -> usize {
        self.shared.accept_errors.load(Ordering::SeqCst)
    }

    /// Currently-open client connections (event-loop acceptor; the
    /// legacy path reports 0 — it has no central connection table).
    pub fn open_connections(&self) -> usize {
        self.shared.open_conns.load(Ordering::SeqCst)
    }

    /// Connections closed for exceeding the header deadline.
    pub fn slow_client_closes(&self) -> usize {
        self.shared.slow_client_closes.load(Ordering::SeqCst)
    }

    /// Connections upgraded to the bin1 binary frame dialect.
    pub fn frame_upgrades(&self) -> usize {
        self.shared.frame_upgrades.load(Ordering::SeqCst)
    }

    /// The `retry_after_ms` hint currently shipped in `overloaded`
    /// responses (derived from the live linger value, not construction
    /// time).
    pub fn retry_after_ms(&self) -> u64 {
        self.shared.retry_after_ms()
    }

    /// Config hot-reload hook: update the linger window the `overloaded`
    /// retry hint is derived from.  Clamped to ≥ 1 ms on read, so a
    /// zero linger never tells clients to retry immediately in a tight
    /// loop.
    pub fn set_linger_ms(&self, ms: u64) {
        self.shared.linger_ms.store(ms, Ordering::SeqCst);
    }

    /// Clone of the coalescer's job sender: lets an embedder (or the
    /// soak tests) run [`ExecJob`]s on the coordinator thread alongside
    /// live traffic.  `None` once shutdown has begun.  The coalescer
    /// drains only after every clone is dropped — holders must not
    /// outlive the shutdown they expect to observe.
    pub fn coordinator_handle(&self) -> Option<Sender<Job>> {
        lock_unpoisoned(&self.shared.jobs_tx).clone()
    }

    /// Answer requests until a `shutdown` request arrives, then join every
    /// thread.  Runs the coalescer on the calling thread — the PJRT
    /// artifacts are not Sync, so they stay with the coordinator (the same
    /// design as the cluster campaign).
    pub fn run(&self, arts: Option<&Artifacts>) -> Result<(), Error> {
        self.shared.coalescer.run(arts);
        for h in lock_unpoisoned(&self.handles).drain(..) {
            let _ = h.join();
        }
        Ok(())
    }
}

/// Largest accepted request line; a predict request is <200 bytes, so
/// 64 KiB is generous while bounding per-connection memory.  (Public so
/// the conformance tests probe the real boundary.)
pub const MAX_REQUEST_BYTES: usize = 64 * 1024;

/// The legacy per-connection loop, rewritten onto the same framing
/// layer ([`conn::extract_frame`]) the event loop uses: blocking socket,
/// periodic read timeouts (shutdown + header-deadline checks), both
/// frame dialects, and the same per-frame assembly bound — so a slow
/// sender can pin this worker for at most `header_deadline`, not
/// forever (the 250 ms-WouldBlock-retry-forever bug this PR retires).
fn handle_conn(
    stream: TcpStream,
    shared: &Shared,
    jobs: &Sender<Job>,
) -> std::io::Result<()> {
    // Periodic read timeouts let idle keep-alive connections notice
    // shutdown (and slow partials their deadline) instead of pinning
    // their worker forever.
    stream.set_read_timeout(Some(Duration::from_millis(250)))?;
    let mut rbuf = ByteQueue::new();
    let mut dialect = FrameDialect::Jsonl;
    let mut partial_since: Option<Instant> = None;
    let mut chunk = [0u8; 8 * 1024];
    loop {
        match extract_frame(dialect, &mut rbuf, MAX_REQUEST_BYTES) {
            Extract::Frame(request) => {
                // The assembly clock restarts per frame; leftover bytes
                // are the next request's partial.
                partial_since = if rbuf.is_empty() {
                    None
                } else {
                    Some(Instant::now())
                };
                if request.is_empty() {
                    continue;
                }
                let (response, directive) = respond(&request, shared, jobs);
                write_frame(&stream, dialect, &response.to_string_compact())?;
                match directive {
                    ConnDirective::Continue => {}
                    ConnDirective::Close => break,
                    ConnDirective::SwitchDialect(d) => dialect = d,
                }
            }
            Extract::Violation(msg) => {
                let err = protocol::error_json(msg);
                write_frame(&stream, dialect, &err.to_string_compact())?;
                break;
            }
            Extract::Incomplete => {
                if rbuf.is_empty() {
                    partial_since = None;
                } else if partial_since.is_none() {
                    partial_since = Some(Instant::now());
                }
                if header_deadline_expired(partial_since, shared.header_deadline) {
                    shared.slow_client_closes.fetch_add(1, Ordering::SeqCst);
                    let err = protocol::error_json("request header deadline exceeded");
                    write_frame(&stream, dialect, &err.to_string_compact())?;
                    break;
                }
                match (&stream).read(&mut chunk) {
                    Ok(0) => break, // EOF
                    Ok(n) => rbuf.push(chunk.get(..n).unwrap_or(&[])),
                    Err(e)
                        if e.kind() == std::io::ErrorKind::WouldBlock
                            || e.kind() == std::io::ErrorKind::TimedOut =>
                    {
                        // Partial bytes (if any) stay accumulated.
                        if shared.shutdown.load(Ordering::SeqCst) {
                            break;
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(_) => break,
                }
            }
        }
    }
    Ok(())
}

/// Whether a partial frame has outlived the assembly bound (zero
/// disables).  Pure, so the policy is unit-testable without a socket.
fn header_deadline_expired(partial_since: Option<Instant>, bound: Duration) -> bool {
    if bound.is_zero() {
        return false;
    }
    partial_since.map_or(false, |t| t.elapsed() > bound)
}

/// Blocking write of one framed response (legacy path).
fn write_frame(
    mut stream: &TcpStream,
    dialect: FrameDialect,
    payload: &str,
) -> std::io::Result<()> {
    let mut bytes = Vec::with_capacity(payload.len() + 8);
    conn::encode_frame(dialect, payload, &mut bytes);
    stream.write_all(&bytes)
}

/// Build the response for one request frame, plus what the connection
/// loop (either acceptor) should do after writing it.
fn respond(request: &str, shared: &Shared, jobs: &Sender<Job>) -> (Json, ConnDirective) {
    use ConnDirective::Continue;
    // Admission time: deadlines and elapsed_ms are measured from here, so
    // the budget covers parsing, table/profile resolution, queueing, and
    // the batch itself.
    let t0 = Instant::now();
    let (v, parsed) = protocol::parse_request(request);
    let req = match parsed {
        Err(e) => return (protocol::error_response(v, &e), Continue),
        Ok(r) => r,
    };
    match req {
        Request::Status => (status_json(shared, v), Continue),
        Request::Metrics => (
            protocol::metrics_json(&protocol::prometheus_text(&counters(shared))),
            Continue,
        ),
        Request::Shutdown => {
            // The acceptor polls this flag (non-blocking accept loop /
            // event-loop tick) and idle connections see it via their
            // read timeouts or the shutdown sweep.  Dropping the
            // embedder-facing job sender lets the coalescer drain once
            // the workers exit.
            shared.shutdown.store(true, Ordering::SeqCst);
            lock_unpoisoned(&shared.jobs_tx).take();
            (protocol::ack_json("shutting down"), ConnDirective::Close)
        }
        Request::Frames { format } => match format.as_str() {
            "bin1" => {
                shared.frame_upgrades.fetch_add(1, Ordering::SeqCst);
                (
                    protocol::frames_ack_json("bin1"),
                    ConnDirective::SwitchDialect(FrameDialect::Bin1),
                )
            }
            "jsonl" => (
                protocol::frames_ack_json("jsonl"),
                ConnDirective::SwitchDialect(FrameDialect::Jsonl),
            ),
            other => (
                protocol::error_response(
                    v,
                    &Error::BadRequest(format!("unknown frame format '{other}' (jsonl|bin1)")),
                ),
                Continue,
            ),
        },
        Request::Predict {
            arch,
            workload,
            mode,
            duration_s,
            deadline,
        } => {
            let Some(permit) = shared.queue.try_acquire_owned() else {
                shared.rejected.fetch_add(1, Ordering::SeqCst);
                return (protocol::overloaded_json(v, shared.retry_after_ms()), Continue);
            };
            let deadline_at =
                effective_deadline(deadline, shared.default_deadline).map(|d| t0 + d);
            let outcome = engine_for(shared, jobs, &arch).and_then(|engine| {
                engine.predict(PredictRequest {
                    workload: Some(workload),
                    mode,
                    duration_s,
                    deadline: deadline_at,
                    permit: Some(permit),
                    ..PredictRequest::default()
                })
            });
            match outcome {
                Ok(out) => {
                    shared.served.fetch_add(1, Ordering::SeqCst);
                    (protocol::prediction_json(&out.prediction), Continue)
                }
                Err(e) => (failure_json(shared, e, t0, v), Continue),
            }
        }
        Request::PredictAll {
            arch,
            mode,
            duration_s,
            deadline,
        } => {
            let Some(permit) = shared.queue.try_acquire_owned() else {
                shared.rejected.fetch_add(1, Ordering::SeqCst);
                return (protocol::overloaded_json(v, shared.retry_after_ms()), Continue);
            };
            let deadline_at =
                effective_deadline(deadline, shared.default_deadline).map(|d| t0 + d);
            let outcome = engine_for(shared, jobs, &arch).and_then(|engine| {
                engine.predict_suite(PredictRequest {
                    workload: None,
                    mode,
                    duration_s,
                    deadline: deadline_at,
                    permit: Some(permit),
                    ..PredictRequest::default()
                })
            });
            match outcome {
                Ok(outs) => {
                    shared.served.fetch_add(1, Ordering::SeqCst);
                    let preds: Vec<Prediction> =
                        outs.into_iter().map(|o| o.prediction).collect();
                    (protocol::predict_all_json(&arch, &preds), Continue)
                }
                Err(e) => (failure_json(shared, e, t0, v), Continue),
            }
        }
        Request::Advise {
            arch,
            workload,
            mode,
            duration_s,
            deadline,
            objective,
        } => {
            let Some(permit) = shared.queue.try_acquire_owned() else {
                shared.rejected.fetch_add(1, Ordering::SeqCst);
                return (protocol::overloaded_json(v, shared.retry_after_ms()), Continue);
            };
            let deadline_at =
                effective_deadline(deadline, shared.default_deadline).map(|d| t0 + d);
            let outcome = engine_for(shared, jobs, &arch).and_then(|engine| {
                engine.sweep(SweepRequest {
                    workload,
                    mode,
                    duration_s,
                    objective,
                    deadline: deadline_at,
                    permit: Some(permit),
                    ..SweepRequest::default()
                })
            });
            match outcome {
                Ok(advice) => {
                    shared.served.fetch_add(1, Ordering::SeqCst);
                    (protocol::advise_json(&advice), Continue)
                }
                Err(e) => (failure_json(shared, e, t0, v), Continue),
            }
        }
    }
}

/// The per-request engine handle: arch catalog lookup, registry table
/// (hot reload), the serve coalescer, and the counter-instrumented
/// profile cache.  Each failure is the typed error whose v1 rendering is
/// byte-identical to the legacy flat strings.
fn engine_for(shared: &Shared, jobs: &Sender<Job>, arch: &str) -> Result<Engine, Error> {
    let cfg = ArchConfig::by_name(arch).ok_or_else(|| Error::unknown_arch(arch))?;
    let table = shared.registry.get(arch)?;
    Ok(Engine::for_service(
        cfg,
        table,
        jobs.clone(),
        shared.profiles.clone(),
        shared.eval_cache.clone(),
        shared.default_duration_s,
    ))
}

/// Retry delay after the `n`-th *consecutive* accept() failure: bounded
/// exponential, 20 ms doubling to a 1 s ceiling, deterministic (no
/// jitter — a single acceptor thread has no peers to desynchronize
/// from).  Pure so the schedule is unit-testable without a socket.
fn accept_backoff(consecutive_errors: u32) -> Duration {
    const SCHEDULE: Backoff = Backoff {
        base: Duration::from_millis(20),
        max: Duration::from_secs(1),
        jitter_frac: 0.0,
    };
    SCHEDULE.delay(consecutive_errors, 0.0)
}

/// The budget actually enforced: a per-request `deadline_ms` may only
/// tighten the server-wide one — never extend it, or a hostile client
/// could pin queue slots past the operator's ceiling.
fn effective_deadline(requested: Option<Duration>, server: Option<Duration>) -> Option<Duration> {
    match (requested, server) {
        (Some(r), Some(s)) => Some(r.min(s)),
        (r, s) => r.or(s),
    }
}

/// Classify a failed predict-family request into exactly one counter and
/// its dialect-appropriate error response.
fn failure_json(shared: &Shared, e: Error, t0: Instant, v: Proto) -> Json {
    match e {
        Error::DeadlineExceeded => {
            shared.deadline_exceeded.fetch_add(1, Ordering::SeqCst);
            protocol::deadline_error_json(v, t0.elapsed())
        }
        e => {
            shared.request_errors.fetch_add(1, Ordering::SeqCst);
            protocol::error_response(v, &e)
        }
    }
}

/// Snapshot of the service counters (shared by `status` and `metrics`).
fn counters(shared: &Shared) -> protocol::ServiceCounters {
    protocol::ServiceCounters {
        served: shared.served.load(Ordering::SeqCst),
        rejected: shared.rejected.load(Ordering::SeqCst),
        deadline_exceeded: shared.deadline_exceeded.load(Ordering::SeqCst),
        request_errors: shared.request_errors.load(Ordering::SeqCst),
        batched_predict_calls: shared.coalescer.batch_calls(),
        table_reloads: shared.registry.reloads(),
        profile_cache_hits: shared.profiles.hits(),
        profile_cache_misses: shared.profiles.misses(),
        accept_errors: shared.accept_errors.load(Ordering::SeqCst),
        open_connections: shared.open_conns.load(Ordering::SeqCst),
        slow_client_closes: shared.slow_client_closes.load(Ordering::SeqCst),
        frame_upgrades: shared.frame_upgrades.load(Ordering::SeqCst),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_deadline_only_tightens_the_server_budget() {
        let ms = Duration::from_millis;
        // Client alone / server alone / neither.
        assert_eq!(effective_deadline(Some(ms(50)), None), Some(ms(50)));
        assert_eq!(effective_deadline(None, Some(ms(100))), Some(ms(100)));
        assert_eq!(effective_deadline(None, None), None);
        // Both set: minimum wins in either direction — a hostile
        // deadline_ms must not extend the operator's ceiling.
        assert_eq!(effective_deadline(Some(ms(50)), Some(ms(100))), Some(ms(50)));
        assert_eq!(effective_deadline(Some(ms(86_400_000)), Some(ms(100))), Some(ms(100)));
    }

    #[test]
    fn retry_after_hint_tracks_linger_hot_reload() {
        // Build a Shared directly (no listener needed): the hint must
        // be derived from the *current* linger value at response time,
        // not frozen at construction — the staleness bug this PR fixes.
        let (coalescer, jobs_tx) = Coalescer::new(Duration::from_millis(25));
        let shared = Shared {
            addr: "127.0.0.1:0".parse().unwrap(),
            registry: TableRegistry::new(PathBuf::from(".")),
            profiles: Arc::new(ProfileCache::new()),
            eval_cache: Arc::new(EvalCache::new()),
            coalescer,
            queue: Arc::new(Semaphore::new(1)),
            jobs_tx: Mutex::new(Some(jobs_tx)),
            shutdown: AtomicBool::new(false),
            served: AtomicUsize::new(0),
            rejected: AtomicUsize::new(0),
            deadline_exceeded: AtomicUsize::new(0),
            request_errors: AtomicUsize::new(0),
            accept_errors: AtomicUsize::new(0),
            open_conns: AtomicUsize::new(0),
            slow_client_closes: AtomicUsize::new(0),
            frame_upgrades: AtomicUsize::new(0),
            default_duration_s: WORKLOAD_SECS,
            default_deadline: None,
            header_deadline: Duration::from_secs(10),
            linger_ms: AtomicU64::new(25),
        };
        assert_eq!(shared.retry_after_ms(), 25);
        // Hot-reload shrinks the batch window: the hint follows.
        shared.linger_ms.store(3, Ordering::SeqCst);
        assert_eq!(shared.retry_after_ms(), 3);
        // Zero linger clamps to 1 ms — never "retry immediately".
        shared.linger_ms.store(0, Ordering::SeqCst);
        assert_eq!(shared.retry_after_ms(), 1);
        // The overloaded response carries the live value.
        let j = protocol::overloaded_json(Proto::V2, shared.retry_after_ms());
        assert_eq!(j.get("retry_after_ms").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn header_deadline_policy_is_pure_and_bounded() {
        // checked_sub: Instant cannot underflow even on short uptimes.
        let old = Instant::now()
            .checked_sub(Duration::from_millis(50))
            .expect("uptime exceeds 50ms");
        // Disabled bound never expires anything, however old.
        assert!(!header_deadline_expired(Some(old), Duration::ZERO));
        // No partial frame → nothing to expire.
        assert!(!header_deadline_expired(None, Duration::from_millis(1)));
        // A partial older than the bound is expired...
        assert!(header_deadline_expired(Some(old), Duration::from_millis(10)));
        // ...while a fresh partial survives a generous one.
        assert!(!header_deadline_expired(
            Some(Instant::now()),
            Duration::from_secs(10)
        ));
    }

    #[test]
    fn accept_backoff_is_bounded_exponential() {
        let ms = Duration::from_millis;
        assert_eq!(accept_backoff(0), ms(20));
        assert_eq!(accept_backoff(1), ms(40));
        assert_eq!(accept_backoff(2), ms(80));
        assert_eq!(accept_backoff(3), ms(160));
        // Capped at the 1 s ceiling from the 6th failure on, forever.
        assert_eq!(accept_backoff(6), ms(1000));
        assert_eq!(accept_backoff(100), ms(1000));
        assert_eq!(accept_backoff(u32::MAX), ms(1000));
        // Monotone non-decreasing across the whole ramp.
        for n in 0..12 {
            assert!(accept_backoff(n) <= accept_backoff(n + 1));
        }
    }
}

/// The `status` response.  v1 keeps the legacy bare-counter shape
/// byte-identical; v2 adds the `capabilities` handshake object.
fn status_json(shared: &Shared, v: Proto) -> Json {
    let c = counters(shared);
    let mut pairs = vec![
        ("ok", Json::Bool(true)),
        ("served", Json::Num(c.served as f64)),
        ("rejected", Json::Num(c.rejected as f64)),
        ("deadline_exceeded", Json::Num(c.deadline_exceeded as f64)),
        ("request_errors", Json::Num(c.request_errors as f64)),
        (
            "batched_predict_calls",
            Json::Num(c.batched_predict_calls as f64),
        ),
        ("table_reloads", Json::Num(c.table_reloads as f64)),
        ("profile_cache_hits", Json::Num(c.profile_cache_hits as f64)),
        (
            "profile_cache_misses",
            Json::Num(c.profile_cache_misses as f64),
        ),
        ("accept_errors", Json::Num(c.accept_errors as f64)),
    ];
    if v == Proto::V2 {
        pairs.push(("capabilities", protocol::capabilities_json()));
    }
    Json::obj(pairs)
}
