//! Benchmark harness (`cargo bench`) — criterion is unavailable offline,
//! so this is a custom `harness = false` driver: warmup + N samples,
//! median/min/max wall times per benchmark.  PERF.md records the tracked
//! medians per PR; pass `--json <path>` (e.g. `cargo bench -- --json
//! BENCH_$(date +%F).json`) to emit them machine-readably.
//!
//! One end-to-end benchmark per experiment family (see PERF.md):
//!   campaign_v100        — Fig 3/6 training pipeline (collect+reduce+solve)
//!   predict_sweep_v100   — Fig 6 prediction phase over the 16 workloads
//!   measure_suite_v100   — ground-truth "Real GPU (D)" measurement loop
//!   nnls_{artifact,native}      — the §3.1 solver on a 90×90 system
//!   integrate_{artifact,native} — the §3.3 batched trace integration
//!   device_sim           — raw simulator substrate throughput
//!   affine_transfer      — Fig 14 transfer fit
//!   case_study_backprop  — Fig 10/11 pipeline
//!   serve_batch_64       — 64-request burst through `wattchmen serve`
//!   serve_predict_all    — the whole 16-workload suite answered by ONE
//!                          `predict_all` request (vs 64 single requests
//!                          above; the control is predict_sweep_v100)
//!   serve_idle_4k        — single-predict latency while ~4096 idle
//!                          keep-alive connections sit parked in the
//!                          readiness-loop acceptor (fd budget
//!                          permitting; the note reports the herd size)
//!   compare_models_v100  — memoized compare_models steady state (the
//!                          warmup pays training+measurement once; timed
//!                          samples are all EvalCache hits)
//!   report_all_fast      — full `report all --fast` pipeline, parallel
//!                          figure drivers over a fresh cache
//!   fleet_10k_day        — 10 000-device × 24 h fleet campaign on the
//!                          host's worker pool (plans resolved in setup;
//!                          the timed region is pure simulation + merge)
//!   fleet_10k_day_jobs1  — the same campaign on ONE worker: the ratio
//!                          to fleet_10k_day is the parallel speedup
//!   daemon_overhead_*    — `wattchmen daemon` supervised loop at three
//!                          sampling intervals (0 µs / 500 µs / 2 ms);
//!                          the note reports supervisor wakeups/sec
//!   advise_sweep_v100    — PR 10 DVFS advisor: `Engine::sweep` over the
//!                          16-workload suite — ONE coalesced predict
//!                          pass expanded post-predict to 11-step curves
//!   advise_single        — warm-table advise for one workload prefix
//!                          (`backprop`), the latency a `{"cmd":"advise"}`
//!                          request pays once the table is resident
//!
//! Each benchmark also prints the headline numbers it reproduces so
//! `cargo bench` doubles as a quick regeneration harness.  Pass
//! `--filter <substring>` to run only matching benchmarks (expensive
//! setup for non-matching groups is skipped too).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::{Arc, OnceLock};
use std::thread;
use std::time::{Duration, Instant};

use wattchmen::cluster::ClusterCampaign;
use wattchmen::daemon::{self, faults::FaultPlan, DaemonConfig};
use wattchmen::fleet;
use wattchmen::gpusim::config::ArchConfig;
use wattchmen::gpusim::device::Device;
use wattchmen::gpusim::kernel::KernelSpec;
use wattchmen::gpusim::profiler::profile_app;
use wattchmen::isa::Gen;
use wattchmen::model::{self, Mode, TrainConfig};
use wattchmen::report::{self, measure_workload, scaled_workload, EvalCache, EvalCtx};
use wattchmen::runtime::Artifacts;
use wattchmen::service::{protocol, PredictServer, ServeConfig};
use wattchmen::solver::{nnls as native_nnls, Mat};
use wattchmen::trace;
use wattchmen::util::json::Json;
use wattchmen::util::prng::Rng;
use wattchmen::util::stats;
use wattchmen::workloads;
use wattchmen::{Engine, SweepRequest};

/// `--filter <substring>` from argv; benchmarks whose name doesn't
/// contain it are skipped (and guarded setup blocks with them).
static FILTER: OnceLock<Option<String>> = OnceLock::new();

fn selected(name: &str) -> bool {
    match FILTER.get().and_then(|f| f.as_deref()) {
        Some(f) => name.contains(f),
        None => true,
    }
}

fn bench<F: FnMut() -> String>(
    name: &str,
    iters: usize,
    results: &mut Vec<(String, f64)>,
    mut f: F,
) {
    if !selected(name) {
        return;
    }
    let mut note = f(); // warmup
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        note = f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let med = stats::median(&samples);
    let min = samples.iter().cloned().fold(f64::MAX, f64::min);
    let max = samples.iter().cloned().fold(f64::MIN, f64::max);
    println!("{name:<26} median {med:>10.2} ms   min {min:>10.2}   max {max:>10.2}   [{note}]");
    results.push((name.to_string(), med));
}

/// `--json <path>`: emit per-benchmark medians (ms) for the PERF.md
/// trajectory; unknown flags (e.g. cargo's own) are ignored.
fn json_path_from_args() -> Option<PathBuf> {
    let argv: Vec<String> = std::env::args().collect();
    let mut i = 1;
    while i < argv.len() {
        if argv[i] == "--json" {
            match argv.get(i + 1) {
                Some(p) => return Some(PathBuf::from(p)),
                None => {
                    eprintln!("--json requires a path argument");
                    std::process::exit(2);
                }
            }
        }
        i += 1;
    }
    None
}

/// `--filter <substring>`: run only benchmarks whose name contains the
/// substring (same manual argv scan as `--json` — cargo's own flags
/// pass through untouched).
fn filter_from_args() -> Option<String> {
    let argv: Vec<String> = std::env::args().collect();
    let mut i = 1;
    while i < argv.len() {
        if argv[i] == "--filter" {
            match argv.get(i + 1) {
                Some(f) => return Some(f.clone()),
                None => {
                    eprintln!("--filter requires a substring argument");
                    std::process::exit(2);
                }
            }
        }
        i += 1;
    }
    None
}

fn write_json(path: &PathBuf, results: &[(String, f64)]) {
    let unix_time = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let doc = Json::obj(vec![
        ("schema", Json::Str("wattchmen-bench-v1".into())),
        ("unix_time", Json::Num(unix_time as f64)),
        (
            "median_ms",
            Json::Obj(
                results
                    .iter()
                    .map(|(n, m)| (n.clone(), Json::Num(*m)))
                    .collect(),
            ),
        ),
    ]);
    match std::fs::write(path, doc.to_string_pretty()) {
        Ok(()) => println!("bench medians written to {}", path.display()),
        Err(e) => {
            eprintln!("failed to write {}: {e}", path.display());
            std::process::exit(1);
        }
    }
}

fn fast_tc() -> TrainConfig {
    TrainConfig {
        reps: 2,
        bench_secs: 60.0,
        cooldown_secs: 15.0,
        idle_secs: 20.0,
        cov_threshold: 0.02,
    }
}

fn system_90(rng: &mut Rng) -> (Vec<Vec<f64>>, Vec<f64>) {
    let n = 90;
    let mut rows = Vec::with_capacity(n);
    for i in 0..n {
        let mut row: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 0.05)).collect();
        row[i] = rng.uniform(0.7, 0.95);
        rows.push(row);
    }
    let x: Vec<f64> = (0..n).map(|_| rng.uniform(0.2, 5.0)).collect();
    let b = Mat::from_rows(&rows).mul_vec(&x);
    (rows, b)
}

fn main() {
    println!("wattchmen bench harness (criterion unavailable offline — custom timer)\n");
    FILTER.set(filter_from_args()).unwrap();
    let json_path = json_path_from_args();
    let mut results: Vec<(String, f64)> = Vec::new();
    let arts = Artifacts::load_default().ok();
    if arts.is_none() {
        println!("NOTE: artifacts missing — artifact benches will be skipped\n");
    }
    let cfg = ArchConfig::cloudlab_v100();

    // --- device simulator substrate ---
    bench("device_sim", 5, &mut results, || {
        let mut dev = Device::new(cfg.clone(), 3);
        let spec = KernelSpec::new("b", vec![("FFMA".into(), 1.0)]).with_issue_eff(0.45);
        let rec = dev.run(&spec, Some(600.0));
        format!("{} samples simulated", rec.telemetry.samples.len())
    });

    // --- solver: PJRT artifact vs native ---
    let mut rng = Rng::new(9);
    let (rows, b) = system_90(&mut rng);
    let flat: Vec<f64> = rows.iter().flatten().cloned().collect();
    if let Some(arts) = arts.as_ref() {
        bench("nnls_artifact_90x90", 10, &mut results, || {
            let x = arts.nnls(&flat, 90, 90, &b).unwrap();
            format!("x[0]={:.3}", x[0])
        });
    }
    bench("nnls_native_90x90", 10, &mut results, || {
        let (x, res) = native_nnls(&Mat::from_rows(&rows), &b);
        format!("x[0]={:.3} res={res:.1e}", x[0])
    });

    // --- trace integration: artifact vs native ---
    let traces: Vec<Vec<f64>> = (0..90)
        .map(|i| {
            let mut r = Rng::new(100 + i);
            (0..1800).map(|_| r.uniform(120.0, 260.0)).collect()
        })
        .collect();
    let windows: Vec<(usize, usize)> = vec![(450, 1800); 90];
    if let Some(arts) = arts.as_ref() {
        bench("integrate_artifact_90", 10, &mut results, || {
            let out = arts.integrate(&traces, &windows, 0.1).unwrap();
            format!("E[0]={:.0} J", out[0].0)
        });
    }
    bench("integrate_native_90", 10, &mut results, || {
        let mut acc = 0.0;
        for (t, &(lo, hi)) in traces.iter().zip(&windows) {
            let w = trace::SteadyWindow { start: lo, end: hi };
            acc += trace::integrate_native(t, w, 0.1).0;
        }
        format!("sumE={acc:.0} J")
    });

    // --- training campaign (Fig 3/6 pipeline) ---
    bench("campaign_v100", 3, &mut results, || {
        let r = ClusterCampaign::new(cfg.clone(), 4, 42)
            .train(&fast_tc(), arts.as_ref())
            .unwrap();
        format!("{} cols residual {:.1e}", r.columns.len(), r.residual)
    });

    // --- prediction sweep (Fig 6 prediction phase) ---
    let suite = workloads::evaluation_suite(Gen::Volta);
    // The trained table feeds predict_sweep and the serve benches; the
    // campaign is skipped when --filter excludes them all.
    let need_table = [
        "predict_sweep_v100",
        "serve_predict_all",
        "serve_batch_64",
        "serve_idle_4k",
        "advise_sweep_v100",
        "advise_single",
    ]
    .iter()
    .any(|n| selected(n));
    let table = need_table.then(|| {
        ClusterCampaign::new(cfg.clone(), 4, 42)
            .train(&fast_tc(), arts.as_ref())
            .unwrap()
            .table
    });
    if let Some(table) = table.as_ref() {
        let profiles: Vec<(String, Vec<_>)> = suite
            .iter()
            .map(|w| {
                let sw = scaled_workload(&cfg, w, 90.0);
                (w.name.clone(), profile_app(&cfg, &sw.kernels))
            })
            .collect();
        bench("predict_sweep_v100", 10, &mut results, || {
            let preds = model::predict_suite(table, &profiles, Mode::Pred, arts.as_ref()).unwrap();
            format!(
                "16 workloads, sum={:.0} J",
                preds.iter().map(|p| p.energy_j).sum::<f64>()
            )
        });

        // --- DVFS advisor (PR 10): sweep cost on a warm table.  Each
        // call is ONE coalesced predict pass; the curve expansion and
        // sweet-spot scan ride post-predict, so the delta over
        // predict_sweep_v100 is the advisor's own overhead.
        let advise_engine = Engine::builder()
            .arch("cloudlab-v100")
            .table(Arc::new(table.clone()))
            .build()
            .unwrap();
        let advise_jobs = thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
        bench("advise_sweep_v100", 10, &mut results, || {
            let advice = advise_engine
                .sweep(SweepRequest {
                    jobs: advise_jobs,
                    ..SweepRequest::default()
                })
                .unwrap();
            let best = advice
                .spots
                .iter()
                .map(|s| s.savings_frac)
                .fold(0.0f64, f64::max);
            format!(
                "{} curves x {} steps, best save {:.1}%",
                advice.curves.len(),
                advice.space.steps.len(),
                100.0 * best
            )
        });
        bench("advise_single", 10, &mut results, || {
            let advice = advise_engine
                .sweep(SweepRequest {
                    workload: Some("backprop".into()),
                    ..SweepRequest::default()
                })
                .unwrap();
            format!(
                "{} kernels, {} steps",
                advice.curves.len(),
                advice.space.steps.len()
            )
        });
    }

    // --- ground-truth measurement loop ("Real GPU (D)") ---
    bench("measure_suite_v100", 3, &mut results, || {
        let mut acc = 0.0;
        for (i, w) in suite.iter().enumerate().take(4) {
            let sw = scaled_workload(&cfg, w, 90.0);
            acc += measure_workload(&cfg, &sw, 50 + i as u64).energy_j;
        }
        format!("4 workloads, sum={acc:.0} J")
    });

    // --- Fig 14 affine transfer ---
    if let Some(arts) = arts.as_ref() {
        let xs: Vec<f64> = (0..90).map(|i| 0.5 + 0.1 * i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 0.9 * x + 0.05).collect();
        bench("affine_transfer", 20, &mut results, || {
            let (s, i) = arts.affine_fit(&xs, &ys).unwrap();
            format!("slope {s:.3} icept {i:.3}")
        });
    }

    // --- case study pipeline (Fig 10/11) ---
    bench("case_study_backprop", 3, &mut results, || {
        let buggy =
            scaled_workload(&cfg, &workloads::rodinia::backprop_k2(Gen::Volta, false), 90.0);
        let fixed =
            scaled_workload(&cfg, &workloads::rodinia::backprop_k2(Gen::Volta, true), 90.0);
        let mb = measure_workload(&cfg, &buggy, 11).energy_j;
        let ma = measure_workload(&cfg, &fixed, 11).energy_j;
        format!("energy drop {:.1}%", 100.0 * (mb - ma) / mb)
    });

    // --- report pipeline: memoized compare_models + parallel figures ---
    {
        // One shared context: the warmup call trains the V100 table and
        // measures the 16-workload suite; every timed sample then runs
        // the full A/G/B/C comparison from cache (PERF.md PR 3).
        let report_ctx = EvalCtx::new(true, 42);
        bench("compare_models_v100", 5, &mut results, || {
            let cmp = report::compare_models(
                &report_ctx,
                &cfg,
                &workloads::evaluation_suite(Gen::Volta),
                &["A", "G", "B", "C"],
            )
            .unwrap();
            format!(
                "Pred MAPE {:.1}%, {} sim measurements total",
                cmp.mape("C"),
                report_ctx.cache().measure_invocations()
            )
        });
    }
    bench("report_all_fast", 1, &mut results, || {
        let names: Vec<String> = report::all_names().iter().map(|s| s.to_string()).collect();
        let cache = Arc::new(EvalCache::new());
        let jobs = thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
        let out = report::run_all(&names, true, 42, jobs, arts.as_ref(), &cache, |_, _, _| {});
        let errors = out.iter().filter(|(_, r)| r.is_err()).count();
        format!(
            "{} figures, {} measurements, {} trained archs, {} errors",
            out.len(),
            cache.measure_invocations(),
            cache.trained_archs(),
            errors
        )
    });

    // --- fleet campaign: 10k devices × 24 h, closed-form segments ---
    if selected("fleet_10k_day") || selected("fleet_10k_day_jobs1") {
        let fc = fleet::FleetConfig {
            devices: 10_000,
            hours: 24.0,
            seed: 42,
            jobs: thread::available_parallelism().map(|p| p.get()).unwrap_or(4),
            fast: true,
            ..fleet::FleetConfig::default()
        };
        // Setup, untimed: one training campaign + one suite prediction
        // per architecture in the mix.  The timed region is pure
        // trace generation + closed-form simulation + in-order merge.
        let cache = Arc::new(EvalCache::new());
        let plans = fleet::resolve_plans(&fc, &cache).unwrap();
        let headline = |rep: &fleet::FleetReport, workers: usize| {
            format!(
                "{:.1} MWh, {} jobs, peak {:.0} kW, {} workers",
                rep.total_energy_j / 3.6e9,
                rep.jobs,
                rep.peak_bin_power_w / 1e3,
                workers
            )
        };
        bench("fleet_10k_day", 3, &mut results, || {
            let rep = fleet::run(&fc, &plans).unwrap();
            headline(&rep, fc.jobs)
        });
        // One worker, same bytes: the ratio to fleet_10k_day is the
        // worker-pool speedup PERF.md tracks.
        bench("fleet_10k_day_jobs1", 1, &mut results, || {
            let rep = fleet::run(&fleet::FleetConfig { jobs: 1, ..fc.clone() }, &plans).unwrap();
            headline(&rep, 1)
        });
    }

    // --- serve: 64-request concurrent burst through the TCP service ---
    if selected("serve_predict_all") || selected("serve_batch_64") || selected("serve_idle_4k") {
        let table = table.as_ref().expect("need_table covers the serve benches");
        let dir = std::env::temp_dir().join("wattchmen_bench_serve");
        std::fs::create_dir_all(&dir).unwrap();
        table.save(&dir.join("cloudlab-v100.table.json")).unwrap();
        let server = Arc::new(
            PredictServer::bind(ServeConfig {
                addr: "127.0.0.1:0".into(),
                workers: 64,
                linger: Duration::from_millis(5),
                tables_dir: dir,
                default_duration_s: 90.0,
                ..ServeConfig::default()
            })
            .unwrap(),
        );
        let addr = server.local_addr();
        let runner = {
            let server = server.clone();
            // The serving thread runs the batched native path; the artifact
            // predict executable is covered by predict_sweep_v100.
            thread::spawn(move || server.run(None).unwrap())
        };
        let names: Vec<String> = suite.iter().map(|w| w.name.clone()).collect();
        bench("serve_predict_all", 10, &mut results, || {
            let stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut writer = stream;
            let req = protocol::predict_all_request("cloudlab-v100", Mode::Pred);
            writer.write_all(req.to_string_compact().as_bytes()).unwrap();
            writer.write_all(b"\n").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(line.contains("\"ok\":true"), "{line}");
            assert!(line.contains("\"count\":16"), "{line}");
            format!("16 workloads in 1 request, {} B response", line.len())
        });
        bench("serve_batch_64", 5, &mut results, || {
            let mut clients = Vec::new();
            for i in 0..64 {
                let workload = names[i % names.len()].clone();
                clients.push(thread::spawn(move || {
                    let stream = TcpStream::connect(addr).unwrap();
                    let mut reader = BufReader::new(stream.try_clone().unwrap());
                    let mut writer = stream;
                    let req = protocol::predict_request("cloudlab-v100", &workload, Mode::Pred);
                    writer.write_all(req.to_string_compact().as_bytes()).unwrap();
                    writer.write_all(b"\n").unwrap();
                    let mut line = String::new();
                    reader.read_line(&mut line).unwrap();
                    assert!(line.contains("\"ok\":true"), "{line}");
                }));
            }
            for c in clients {
                c.join().unwrap();
            }
            format!("{} batched calls total", server.batch_calls())
        });
        // Idle herd: park as close to 4096 keep-alive connections as the
        // process fd budget allows, then time single predicts flowing
        // past them.  On the old thread-per-connection acceptor this
        // herd would be 4k blocked threads; here it is one poller.
        if selected("serve_idle_4k") && cfg!(unix) {
            let mut herd = Vec::new();
            for _ in 0..4096 {
                match TcpStream::connect(addr) {
                    Ok(s) => herd.push(s),
                    Err(_) => break, // fd budget (EMFILE) — note the size below
                }
            }
            // Let the acceptor register the whole herd before timing.
            while server.open_connections() < herd.len() {
                thread::sleep(Duration::from_millis(1));
            }
            let herd_size = herd.len();
            bench("serve_idle_4k", 10, &mut results, || {
                let stream = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut writer = stream;
                let req = protocol::predict_request("cloudlab-v100", &names[0], Mode::Pred);
                writer.write_all(req.to_string_compact().as_bytes()).unwrap();
                writer.write_all(b"\n").unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                assert!(line.contains("\"ok\":true"), "{line}");
                format!("1 predict past {herd_size} idle conns")
            });
            drop(herd);
        }
        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        writer.write_all(b"{\"cmd\":\"shutdown\"}\n").unwrap();
        let mut ack = String::new();
        reader.read_line(&mut ack).unwrap();
        runner.join().unwrap();
    }

    // --- daemon: supervised attribution loop at three sampling intervals ---
    // Overhead question: what does supervision + checkpoint plumbing cost
    // per wakeup as the sampler interval shrinks?  No checkpoints, no
    // faults — the timed region is the pure worker/supervisor machinery.
    if selected("daemon_overhead") {
        for &(name, interval_us, samples) in &[
            ("daemon_overhead_0us", 0u64, 20_000u64),
            ("daemon_overhead_500us", 500, 2_000),
            ("daemon_overhead_2ms", 2_000, 500),
        ] {
            bench(name, 3, &mut results, || {
                let cfg = DaemonConfig {
                    samples,
                    interval: Duration::from_micros(interval_us),
                    export_interval: Duration::from_millis(5),
                    checkpoint_dir: None,
                    final_checkpoint: false,
                    ..DaemonConfig::default()
                };
                let batch = cfg.batch as u64;
                let t0 = Instant::now();
                let report = daemon::run(cfg, FaultPlan::default()).unwrap();
                let dt = t0.elapsed().as_secs_f64();
                assert!(report.conserved(), "daemon bench violated conservation");
                assert_eq!(report.ledger.samples, samples);
                let wakeups = samples.div_ceil(batch) + report.export_ticks;
                format!(
                    "{} samples, {:.0} wakeups/s at {} µs interval",
                    report.ledger.samples,
                    wakeups as f64 / dt,
                    interval_us
                )
            });
        }
    }

    if let Some(path) = &json_path {
        write_json(path, &results);
    }
    println!("\nbench complete");
}
