//! The §5.3 case studies: using Wattchmen's fine-grained attribution to
//! find and fix real energy bugs.
//!
//!   * Backprop: two `#define`s silently defaulted to double precision —
//!     F2F.F64.F32 conversions show up as ~25 % of adjust_weights'
//!     instructions; fixing them cuts energy ~16 % at ~1 % runtime cost.
//!   * QMCPACK: the mixed-precision build called the walker-update path
//!     ~2.6× more often than intended; Wattchmen's breakdown localizes the
//!     excess and the fix saves ~35 %.
//!
//!     cargo run --release --example case_studies

use wattchmen::cluster::ClusterCampaign;
use wattchmen::gpusim::config::ArchConfig;
use wattchmen::gpusim::profiler::profile_app;
use wattchmen::isa::Gen;
use wattchmen::model::{predict_app, Mode, TrainConfig};
use wattchmen::report::{measure_workload, scaled_workload};
use wattchmen::runtime::Artifacts;
use wattchmen::workloads::{qmcpack::qmcpack, rodinia::backprop_k2};

fn main() -> Result<(), wattchmen::Error> {
    let arts = Artifacts::load_default().ok();
    let cfg = ArchConfig::cloudlab_v100();
    let tc = TrainConfig {
        reps: 2,
        bench_secs: 60.0,
        cooldown_secs: 15.0,
        idle_secs: 20.0,
        cov_threshold: 0.02,
    };
    println!("training the model once...");
    let table = ClusterCampaign::new(cfg.clone(), 4, 42)
        .train(&tc, arts.as_ref())?
        .table;

    // ---- Case study 1: backprop_k2 ----
    println!("\n=== backprop_k2 (Fig 10/11) ===");
    let buggy = scaled_workload(&cfg, &backprop_k2(Gen::Volta, false), 90.0);
    let profiles = profile_app(&cfg, &buggy.kernels);
    let pred = predict_app(&table, "backprop_k2", &profiles, Mode::Pred);
    println!("attribution flags the conversion pipe:");
    for (key, joules, _) in pred.by_key.iter().take(5) {
        println!("  {key:<18} {joules:>8.0} J");
    }
    let fixed = scaled_workload(&cfg, &backprop_k2(Gen::Volta, true), 90.0);
    let (mb, ma) = (
        measure_workload(&cfg, &buggy, 11).energy_j,
        measure_workload(&cfg, &fixed, 11).energy_j,
    );
    println!(
        "fixing the #define precision: {mb:.0} J → {ma:.0} J  (−{:.1}%, paper: 16%)",
        100.0 * (mb - ma) / mb
    );

    // ---- Case study 2: QMCPACK ----
    println!("\n=== QMCPACK mixed precision (Fig 12/13) ===");
    let buggy_nat = qmcpack(Gen::Volta, false);
    let buggy = scaled_workload(&cfg, &buggy_nat, 90.0);
    let scale = buggy.kernels[0].iters / buggy_nat.kernels[0].iters;
    let mut fixed = qmcpack(Gen::Volta, true);
    for k in &mut fixed.kernels {
        k.iters *= scale;
    }
    // Per-kernel attribution exposes the over-called update path.
    for w in [&buggy, &fixed] {
        let profiles = profile_app(&cfg, &w.kernels);
        let per_kernel: Vec<String> = profiles
            .iter()
            .map(|p| {
                let pr = predict_app(&table, &p.name, std::slice::from_ref(p), Mode::Pred);
                format!("{}={:.0}J", p.name, pr.energy_j)
            })
            .collect();
        println!("  {:<14} {}", w.name, per_kernel.join("  "));
    }
    let (mb, ma) = (
        measure_workload(&cfg, &buggy, 13).energy_j,
        measure_workload(&cfg, &fixed, 13).energy_j,
    );
    let pb = predict_app(&table, "qmcpack", &profile_app(&cfg, &buggy.kernels), Mode::Pred).energy_j;
    let pa = predict_app(&table, "qmcpack_fixed", &profile_app(&cfg, &fixed.kernels), Mode::Pred).energy_j;
    println!(
        "fix removes unnecessary walker updates: predicted −{:.1}% (paper 36%), measured −{:.1}% (paper 35%)",
        100.0 * (pb - pa) / pb,
        100.0 * (mb - ma) / mb
    );
    Ok(())
}
