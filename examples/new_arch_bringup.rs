//! New-architecture bring-up (paper §5.2.3): port Wattchmen to the H100
//! without writing microbenchmarks for its new warp-group instructions
//! (HGMMA, TMA).  Wattchmen-Direct leaves them unattributed; bucketing
//! closes most of the coverage gap.
//!
//!     cargo run --release --example new_arch_bringup

use wattchmen::cluster::ClusterCampaign;
use wattchmen::gpusim::config::ArchConfig;
use wattchmen::gpusim::profiler::profile_app;
use wattchmen::isa::Gen;
use wattchmen::model::{predict_app, Mode, Source, TrainConfig};
use wattchmen::report::{measure_workload, scaled_workload};
use wattchmen::runtime::Artifacts;
use wattchmen::util::stats;
use wattchmen::workloads;

fn main() -> Result<(), wattchmen::Error> {
    let arts = Artifacts::load_default().ok();
    let cfg = ArchConfig::lonestar_h100();
    let tc = TrainConfig {
        reps: 2,
        bench_secs: 60.0,
        cooldown_secs: 15.0,
        idle_secs: 20.0,
        cov_threshold: 0.02,
    };
    println!("bring-up campaign on {} ({:?})...", cfg.name, cfg.gen);
    let result = ClusterCampaign::new(cfg.clone(), 4, 42).train(&tc, arts.as_ref())?;
    println!(
        "table has {} directly-measured instruction groups (no HGMMA/TMA benchmarks)",
        result.columns.len()
    );
    println!("bucket averages available for fallback:");
    for (bucket, avg) in result.table.bucket_averages() {
        println!("  {:<12} {avg:>7.2} nJ", bucket.name());
    }

    // Evaluate the half-precision GEMM — the workload dominated by the
    // uncovered HGMMA warp-group instruction.
    let w = scaled_workload(
        &cfg,
        &workloads::deepbench::gemm(Gen::Hopper, 1, "half"),
        90.0,
    );
    let profiles = profile_app(&cfg, &w.kernels);
    let measured = measure_workload(&cfg, &w, 17).energy_j;
    for mode in [Mode::Direct, Mode::Pred] {
        let p = predict_app(&result.table, &w.name, &profiles, mode);
        println!(
            "\n{mode:?}: {:.0} J vs measured {measured:.0} J (ratio {:.2}), coverage {:.0}%",
            p.energy_j,
            p.energy_j / measured,
            100.0 * p.coverage
        );
        for (key, joules, src) in p.by_key.iter().take(5) {
            println!("  {key:<22} {joules:>8.0} J  [{src:?}]");
        }
        if mode == Mode::Pred {
            let bucketed: f64 = p
                .by_key
                .iter()
                .filter(|(_, _, s)| *s == Source::Bucketed)
                .map(|(_, j, _)| j)
                .sum();
            println!("  energy recovered by bucketing: {bucketed:.0} J");
        }
    }

    // Whole-suite coverage improvement.
    let suite = workloads::evaluation_suite(Gen::Hopper);
    let mut cov_direct = Vec::new();
    let mut cov_pred = Vec::new();
    for w in &suite {
        let sw = scaled_workload(&cfg, w, 90.0);
        let profiles = profile_app(&cfg, &sw.kernels);
        cov_direct.push(predict_app(&result.table, &w.name, &profiles, Mode::Direct).coverage);
        cov_pred.push(predict_app(&result.table, &w.name, &profiles, Mode::Pred).coverage);
    }
    println!(
        "\nmean instruction coverage across 16 workloads: Direct {:.0}% → Pred {:.0}% (paper: 66% → 92%)",
        100.0 * stats::mean(&cov_direct),
        100.0 * stats::mean(&cov_pred)
    );
    Ok(())
}
