//! Quickstart: train a Wattchmen energy table on the simulated air-cooled
//! V100 and predict one workload's energy with a fine-grained breakdown —
//! all through the typed `wattchmen::engine` facade, the same path the
//! CLI and the prediction service use.
//!
//!     cargo run --release --example quickstart
//!
//! Uses the PJRT artifacts when `artifacts/` has been built
//! (`make artifacts`), otherwise falls back to the native solver.

use wattchmen::runtime::Artifacts;
use wattchmen::{Engine, PredictRequest};

fn main() -> Result<(), wattchmen::Error> {
    let arts = Artifacts::load_default()
        .map_err(|e| eprintln!("(artifacts unavailable: {e:#}; using native paths)"))
        .ok();

    // 1. Train on a simulated 4-GPU CloudLab slice with the shortened
    //    `fast` protocol (the paper's full protocol is 5 × 180 s per
    //    benchmark).
    let engine = Engine::builder()
        .arch("cloudlab-v100")
        .seed(42)
        .fast(true)
        .artifacts(arts)
        .build()?;
    println!(
        "training Wattchmen on {} (90 microbenchmarks)...",
        engine.arch().name
    );
    let trained = engine.train()?;
    println!(
        "  constant {:.1} W, static {:.1} W, {} instruction groups, residual {:.2e} ({:?})",
        trained.table.const_power_w,
        trained.table.static_power_w,
        trained.result.columns.len(),
        trained.result.residual,
        trained.result.solver,
    );
    println!("  sample energies [nJ/instr]:");
    for key in ["FFMA", "DFMA", "HMMA.884.F32", "LDG.E.64@L1", "LDG.E.64@DRAM"] {
        println!("    {key:<16} {:>6.2}", trained.table.entries[key]);
    }

    // 2. Predict hotspot's energy and attribute it (top 6 groups).
    let outcome = engine.predict(PredictRequest {
        workload: Some("hotspot".into()),
        top: 6,
        ..PredictRequest::default()
    })?;
    let pred = &outcome.prediction;
    println!(
        "\n{}: predicted {:.0} J over {:.1} s (coverage {:.0}%)",
        pred.workload,
        pred.energy_j,
        pred.duration_s,
        100.0 * pred.coverage
    );
    println!("  constant+static {:.0} J, dynamic {:.0} J", pred.base_j, pred.dynamic_j);
    println!("  dynamic energy by component:");
    for (bucket, joules) in &pred.by_bucket {
        println!("    {bucket:<12} {joules:>8.0} J");
    }
    println!("  top instruction groups:");
    for (key, joules, src) in outcome.top_keys() {
        println!("    {key:<20} {joules:>8.0} J  [{src:?}]");
    }
    Ok(())
}
