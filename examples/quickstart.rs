//! Quickstart: train a Wattchmen energy table on the simulated air-cooled
//! V100 and predict one workload's energy with a fine-grained breakdown.
//!
//!     cargo run --release --example quickstart
//!
//! Uses the PJRT artifacts when `artifacts/` has been built
//! (`make artifacts`), otherwise falls back to the native solver.

use wattchmen::cluster::ClusterCampaign;
use wattchmen::gpusim::config::ArchConfig;
use wattchmen::gpusim::profiler::profile_app;
use wattchmen::isa::Gen;
use wattchmen::model::{predict_app, Mode, TrainConfig};
use wattchmen::report::scaled_workload;
use wattchmen::runtime::Artifacts;
use wattchmen::workloads;

fn main() -> anyhow::Result<()> {
    let arts = Artifacts::load_default()
        .map_err(|e| eprintln!("(artifacts unavailable: {e:#}; using native paths)"))
        .ok();

    // 1. Train on a simulated 4-GPU CloudLab slice with a shortened
    //    protocol (the paper's full protocol is 5 × 180 s per benchmark).
    let cfg = ArchConfig::cloudlab_v100();
    let tc = TrainConfig {
        reps: 2,
        bench_secs: 60.0,
        cooldown_secs: 15.0,
        idle_secs: 20.0,
        cov_threshold: 0.02,
    };
    println!("training Wattchmen on {} (90 microbenchmarks)...", cfg.name);
    let result = ClusterCampaign::new(cfg.clone(), 4, 42).train(&tc, arts.as_ref())?;
    println!(
        "  constant {:.1} W, static {:.1} W, {} instruction groups, residual {:.2e} ({:?})",
        result.table.const_power_w,
        result.table.static_power_w,
        result.columns.len(),
        result.residual,
        result.solver,
    );
    println!("  sample energies [nJ/instr]:");
    for key in ["FFMA", "DFMA", "HMMA.884.F32", "LDG.E.64@L1", "LDG.E.64@DRAM"] {
        println!("    {key:<16} {:>6.2}", result.table.entries[key]);
    }

    // 2. Predict hotspot's energy and attribute it.
    let w = scaled_workload(&cfg, &workloads::rodinia::hotspot(Gen::Volta), 90.0);
    let profiles = profile_app(&cfg, &w.kernels);
    let pred = predict_app(&result.table, &w.name, &profiles, Mode::Pred);
    println!(
        "\n{}: predicted {:.0} J over {:.1} s (coverage {:.0}%)",
        pred.workload,
        pred.energy_j,
        pred.duration_s,
        100.0 * pred.coverage
    );
    println!("  constant+static {:.0} J, dynamic {:.0} J", pred.base_j, pred.dynamic_j);
    println!("  dynamic energy by component:");
    for (bucket, joules) in &pred.by_bucket {
        println!("    {bucket:<12} {joules:>8.0} J");
    }
    println!("  top instruction groups:");
    for (key, joules, src) in pred.by_key.iter().take(6) {
        println!("    {key:<20} {joules:>8.0} J  [{src:?}]");
    }
    Ok(())
}
