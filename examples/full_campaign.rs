//! END-TO-END DRIVER: the complete Wattchmen pipeline on the air-cooled
//! V100 with the paper's full measurement protocol, driven through the
//! typed `wattchmen::engine` facade —
//!
//!   1. idle + NANOSLEEP calibration,
//!   2. the 90-microbenchmark campaign, 5 reps × 180 s with 60 s cooldowns,
//!      sharded over a simulated 4-GPU CloudLab slice,
//!   3. batched steady-state integration + the NNLS solve through the AOT
//!      PJRT artifacts (python never runs here),
//!   4. ground-truth measurement of all 16 evaluation workloads,
//!   5. Wattchmen-Direct / Wattchmen-Pred predictions + MAPE (Fig 6 /
//!      Table 4 reproduction) and per-workload attribution.
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.
//!
//!     make artifacts && cargo run --release --example full_campaign

use std::time::Instant;

use wattchmen::isa::Gen;
use wattchmen::model::Mode;
use wattchmen::report::{measure_workload, scaled_workload};
use wattchmen::runtime::Artifacts;
use wattchmen::util::stats;
use wattchmen::util::text::{f, render_table};
use wattchmen::workloads;
use wattchmen::{Engine, PredictRequest};

fn main() -> Result<(), wattchmen::Error> {
    let t0 = Instant::now();
    let arts = Artifacts::load_default()?; // end-to-end REQUIRES the artifacts
    println!("PJRT artifacts loaded (nnls, integrate, affine_fit, predict)");

    // --- Training campaign: full paper protocol (5 reps × 180 s with
    // 60 s cooldowns — the engine's non-`fast` default) ---
    let engine = Engine::builder()
        .arch("cloudlab-v100")
        .seed(42)
        .artifacts(Some(arts))
        .build()?;
    let cfg = engine.arch().clone();
    println!(
        "running the full campaign on {}: 90 benchmarks × 5 reps × 180s across 4 GPUs...",
        cfg.name
    );
    let trained = engine.train()?;
    println!(
        "trained in {:.1}s wall ({} columns, residual {:.2e}, solver {:?})",
        trained.elapsed.as_secs_f64(),
        trained.result.columns.len(),
        trained.result.residual,
        trained.result.solver
    );

    // --- Workload measurement + prediction ---
    let suite = workloads::evaluation_suite(Gen::Volta);
    let scaled: Vec<_> = suite
        .iter()
        .map(|w| scaled_workload(&cfg, w, 90.0))
        .collect();
    println!("measuring {} workloads (~90 s each, simulated)...", scaled.len());
    let measured: Vec<f64> = scaled
        .iter()
        .enumerate()
        .map(|(i, w)| measure_workload(&cfg, w, 1000 + i as u64).energy_j)
        .collect();

    // Both modes answer the whole suite through the engine — one batched
    // predict_many (and one PJRT executable call) per mode.
    let predict = |mode: Mode| {
        engine.predict_suite(PredictRequest {
            mode,
            ..PredictRequest::default()
        })
    };
    let direct: Vec<_> = predict(Mode::Direct)?.into_iter().map(|o| o.prediction).collect();
    let pred: Vec<_> = predict(Mode::Pred)?.into_iter().map(|o| o.prediction).collect();

    let mut rows = Vec::new();
    for (i, w) in scaled.iter().enumerate() {
        rows.push(vec![
            w.name.clone(),
            f(direct[i].energy_j / measured[i], 2),
            f(pred[i].energy_j / measured[i], 2),
            f(100.0 * pred[i].coverage, 0),
            f(measured[i], 0),
        ]);
    }
    println!(
        "\n{}",
        render_table(
            &["workload", "Direct/D", "Pred/D", "coverage %", "measured D [J]"],
            &rows
        )
    );
    let d_e: Vec<f64> = direct.iter().map(|p| p.energy_j).collect();
    let p_e: Vec<f64> = pred.iter().map(|p| p.energy_j).collect();
    println!(
        "MAPE: Wattchmen-Direct {:.1}% (paper 19) | Wattchmen-Pred {:.1}% (paper 14)",
        stats::mape(&d_e, &measured),
        stats::mape(&p_e, &measured)
    );
    println!(
        "full end-to-end pipeline completed in {:.1}s wall",
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
