//! END-TO-END DRIVER (DESIGN.md §6): the complete Wattchmen pipeline on the
//! air-cooled V100 with the paper's full measurement protocol —
//!
//!   1. idle + NANOSLEEP calibration,
//!   2. the 90-microbenchmark campaign, 5 reps × 180 s with 60 s cooldowns,
//!      sharded over a simulated 4-GPU CloudLab slice,
//!   3. batched steady-state integration + the NNLS solve through the AOT
//!      PJRT artifacts (python never runs here),
//!   4. ground-truth measurement of all 16 evaluation workloads,
//!   5. Wattchmen-Direct / Wattchmen-Pred predictions + MAPE (Fig 6 /
//!      Table 4 reproduction) and per-workload attribution.
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.
//!
//!     make artifacts && cargo run --release --example full_campaign

use std::time::Instant;

use wattchmen::cluster::ClusterCampaign;
use wattchmen::gpusim::config::ArchConfig;
use wattchmen::gpusim::profiler::profile_app;
use wattchmen::isa::Gen;
use wattchmen::model::{predict_suite, Mode, TrainConfig};
use wattchmen::report::{measure_workload, scaled_workload};
use wattchmen::runtime::Artifacts;
use wattchmen::util::stats;
use wattchmen::util::text::{f, render_table};
use wattchmen::workloads;

fn main() -> anyhow::Result<()> {
    let t0 = Instant::now();
    let arts = Artifacts::load_default()?; // end-to-end REQUIRES the artifacts
    println!("PJRT artifacts loaded (nnls, integrate, affine_fit, predict)");

    // --- Training campaign: full paper protocol ---
    let cfg = ArchConfig::cloudlab_v100();
    let tc = TrainConfig::default(); // 5 reps × 180 s, 60 s cooldowns
    println!(
        "running the full campaign on {}: 90 benchmarks × {} reps × {:.0}s across 4 GPUs...",
        cfg.name, tc.reps, tc.bench_secs
    );
    let t_train = Instant::now();
    let result = ClusterCampaign::new(cfg.clone(), 4, 42).train(&tc, Some(&arts))?;
    println!(
        "trained in {:.1}s wall ({} columns, residual {:.2e}, solver {:?})",
        t_train.elapsed().as_secs_f64(),
        result.columns.len(),
        result.residual,
        result.solver
    );

    // --- Workload measurement + prediction ---
    let suite = workloads::evaluation_suite(Gen::Volta);
    let scaled: Vec<_> = suite
        .iter()
        .map(|w| scaled_workload(&cfg, w, 90.0))
        .collect();
    let profiles: Vec<(String, Vec<_>)> = scaled
        .iter()
        .map(|w| (w.name.clone(), profile_app(&cfg, &w.kernels)))
        .collect();
    println!("measuring {} workloads (~90 s each, simulated)...", scaled.len());
    let measured: Vec<f64> = scaled
        .iter()
        .enumerate()
        .map(|(i, w)| measure_workload(&cfg, w, 1000 + i as u64).energy_j)
        .collect();

    let direct = predict_suite(&result.table, &profiles, Mode::Direct, Some(&arts))?;
    let pred = predict_suite(&result.table, &profiles, Mode::Pred, Some(&arts))?;

    let mut rows = Vec::new();
    for (i, w) in scaled.iter().enumerate() {
        rows.push(vec![
            w.name.clone(),
            f(direct[i].energy_j / measured[i], 2),
            f(pred[i].energy_j / measured[i], 2),
            f(100.0 * pred[i].coverage, 0),
            f(measured[i], 0),
        ]);
    }
    println!(
        "\n{}",
        render_table(
            &["workload", "Direct/D", "Pred/D", "coverage %", "measured D [J]"],
            &rows
        )
    );
    let d_e: Vec<f64> = direct.iter().map(|p| p.energy_j).collect();
    let p_e: Vec<f64> = pred.iter().map(|p| p.energy_j).collect();
    println!(
        "MAPE: Wattchmen-Direct {:.1}% (paper 19) | Wattchmen-Pred {:.1}% (paper 14)",
        stats::mape(&d_e, &measured),
        stats::mape(&p_e, &measured)
    );
    println!(
        "full end-to-end pipeline completed in {:.1}s wall",
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
