use std::time::Instant;
use wattchmen::cluster::ClusterCampaign;
use wattchmen::gpusim::config::ArchConfig;
use wattchmen::model::train::TrainConfig;
use wattchmen::runtime::Artifacts;

fn main() {
    let cfg = ArchConfig::cloudlab_v100();
    let tc = TrainConfig { reps: 2, bench_secs: 60.0, cooldown_secs: 15.0, idle_secs: 20.0, cov_threshold: 0.02 };
    let t0 = Instant::now();
    let r = ClusterCampaign::new(cfg.clone(), 4, 42).train(&tc, None).unwrap();
    println!("native path: {:.2}s residual {:.1e}", t0.elapsed().as_secs_f64(), r.residual);
    let arts = Artifacts::load_default().unwrap();
    let t1 = Instant::now();
    let r = ClusterCampaign::new(cfg.clone(), 4, 42).train(&tc, Some(&arts)).unwrap();
    println!("artifact path: {:.2}s residual {:.1e}", t1.elapsed().as_secs_f64(), r.residual);
}
