//! Cooling study (paper §5.2.1 + §6/Fig 14): train on the air-cooled and
//! water-cooled V100s through the typed `wattchmen::engine` facade,
//! quantify the measured energy gap, check the air↔water table
//! linearity, and build a water table from a 10 % measured subset via
//! `Engine::transfer` (the PJRT affine-fit artifact when available).
//!
//!     cargo run --release --example cooling_study

use wattchmen::isa::Gen;
use wattchmen::model::{random_subset, table_r_squared};
use wattchmen::report::{measure_workload, scaled_workload};
use wattchmen::runtime::Artifacts;
use wattchmen::util::stats;
use wattchmen::workloads;
use wattchmen::Engine;

fn main() -> Result<(), wattchmen::Error> {
    // Each engine owns its (optionally loaded) artifacts; the `fast`
    // flag selects the shortened 2 × 60 s campaign protocol.
    let engine_for = |arch: &str| {
        Engine::builder()
            .arch(arch)
            .seed(42)
            .fast(true)
            .artifacts(Artifacts::load_default().ok())
            .build()
    };
    let air_engine = engine_for("cloudlab-v100")?;
    let water_engine = engine_for("summit-v100")?;
    let (air_cfg, water_cfg) = (air_engine.arch().clone(), water_engine.arch().clone());

    println!("training on air-cooled V100...");
    let air = air_engine.train()?;
    println!("training on water-cooled V100...");
    let water = water_engine.train()?;

    // Ground-truth energy gap across the Rodinia set.
    let mut gaps = Vec::new();
    for w in workloads::evaluation_suite(Gen::Volta).iter().take(5) {
        let wa = scaled_workload(&air_cfg, w, 90.0);
        let ww = scaled_workload(&water_cfg, w, 90.0);
        let ea = measure_workload(&air_cfg, &wa, 7).energy_j;
        let ew = measure_workload(&water_cfg, &ww, 7).energy_j;
        gaps.push(100.0 * (ea - ew) / ea);
        println!("  {:<14} air {ea:>8.0} J | water {ew:>8.0} J | gap {:.1}%", w.name, gaps.last().unwrap());
    }
    println!(
        "mean water-cooling energy reduction: {:.1}% (paper: ~12%)",
        stats::mean(&gaps)
    );

    // Table linearity + affine transfer from a 10 % subset.
    let r2 = table_r_squared(&air.table, &water.table);
    println!("air↔water per-instruction energy R² = {r2:.3} (paper: 0.988)");
    let keys = random_subset(&water.table, 0.10, 99)?;
    let subset: std::collections::BTreeMap<String, f64> = keys
        .iter()
        .map(|k| (k.clone(), water.table.entries[k]))
        .collect();
    let transfer = air_engine.transfer(
        &subset,
        water.table.const_power_w,
        water.table.static_power_w,
    )?;
    println!(
        "affine transfer from {} measured instructions: slope {:.3}, intercept {:.3}",
        keys.len(),
        transfer.slope,
        transfer.intercept
    );
    // How close is the transferred table to the fully-measured one?
    let mut errs = Vec::new();
    for (k, &e_true) in &water.table.entries {
        let e_t = transfer.table.entries[k];
        if e_true > 0.05 {
            errs.push(100.0 * ((e_t - e_true) / e_true).abs());
        }
    }
    println!(
        "transferred-vs-measured per-instruction error: median {:.1}%",
        stats::median(&errs)
    );
    Ok(())
}
