//! serve_demo: boot the batched prediction service, fire a 64-request
//! concurrent client burst at it, verify CLI parity and coalescing,
//! answer the whole suite through one `predict_all` request, and shut it
//! down cleanly.  Exit code 0 means the full loop — bind, burst,
//! predict_all, drain, join — completed; CI runs this as the serve smoke
//! test.
//!
//!     cargo run --release --example serve_demo
//!
//! Uses the PJRT artifacts when `artifacts/` has been built (the burst
//! then coalesces into batched `predict` executable calls), otherwise the
//! batched native path.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Barrier};
use std::thread;
use std::time::{Duration, Instant};

use wattchmen::cluster::ClusterCampaign;
use wattchmen::error::Error;
use wattchmen::gpusim::config::ArchConfig;
use wattchmen::gpusim::profiler::profile_app;
use wattchmen::model::{predict_suite, Mode, TrainConfig};
use wattchmen::report::context::WORKLOAD_SECS;
use wattchmen::report::scaled_workload;
use wattchmen::runtime::Artifacts;
use wattchmen::service::{protocol, PredictServer, ServeConfig};
use wattchmen::util::json::{parse, Json};
use wattchmen::workloads;

const BURST: usize = 64;

/// Fire `BURST` concurrent predict requests and check each response
/// against the precomputed CLI result for its workload.  `exact` asks
/// for byte-identical lines (native path); with artifacts the batched
/// f32 key-union ordering can differ from the per-workload calls by
/// ulps, so parity is asserted on the energy within 1e-4 instead.
fn run_burst(
    addr: std::net::SocketAddr,
    names: &[String],
    expected: &Arc<BTreeMap<String, (String, f64)>>,
    exact: bool,
) -> Result<Duration, Error> {
    let barrier = Arc::new(Barrier::new(BURST));
    let t0 = Instant::now();
    let mut clients = Vec::new();
    for i in 0..BURST {
        let workload = names[i % names.len()].clone();
        let expected = expected.clone();
        let barrier = barrier.clone();
        clients.push(thread::spawn(move || -> Result<(), Error> {
            barrier.wait();
            let stream = TcpStream::connect(addr)?;
            let mut reader = BufReader::new(stream.try_clone()?);
            let mut writer = stream;
            let req = protocol::predict_request("cloudlab-v100", &workload, Mode::Pred);
            writer.write_all(req.to_string_compact().as_bytes())?;
            writer.write_all(b"\n")?;
            let mut line = String::new();
            reader.read_line(&mut line)?;
            let resp = parse(line.trim())?;
            if resp.get("ok") != Some(&Json::Bool(true)) {
                return Err(Error::internal(format!(
                    "{workload}: error response {line}"
                )));
            }
            let (cli_line, cli_energy) = &expected[&workload];
            let text = resp.get("text").and_then(Json::as_str).unwrap_or("");
            if exact && text != *cli_line {
                return Err(Error::internal(format!(
                    "{workload}: served line diverged from the CLI\n  served: {text}\n  cli:    {cli_line}"
                )));
            }
            let energy = resp
                .get("energy_j")
                .and_then(Json::as_f64)
                .unwrap_or(f64::NAN);
            if !((energy - cli_energy).abs() <= 1e-4 * cli_energy.abs().max(1.0)) {
                return Err(Error::internal(format!(
                    "{workload}: served energy {energy} J vs CLI {cli_energy} J"
                )));
            }
            Ok(())
        }));
    }
    let mut failure = None;
    for c in clients {
        match c.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => failure = Some(e),
            Err(_) => failure = Some(Error::internal("client thread panicked")),
        }
    }
    match failure {
        Some(e) => Err(e),
        None => Ok(t0.elapsed()),
    }
}

/// One `predict_all` request must answer the whole evaluation suite in a
/// single response, each element matching the precomputed CLI result for
/// its workload (same parity rules as the burst).
fn run_predict_all(
    addr: std::net::SocketAddr,
    names: &[String],
    expected: &Arc<BTreeMap<String, (String, f64)>>,
    exact: bool,
) -> Result<(), Error> {
    let stream = TcpStream::connect(addr)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let req = protocol::predict_all_request("cloudlab-v100", Mode::Pred);
    writer.write_all(req.to_string_compact().as_bytes())?;
    writer.write_all(b"\n")?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let resp = parse(line.trim())?;
    if resp.get("ok") != Some(&Json::Bool(true)) {
        return Err(Error::internal(format!(
            "predict_all error response: {line}"
        )));
    }
    let preds = resp
        .get("predictions")
        .and_then(Json::as_arr)
        .unwrap_or(&[]);
    if preds.len() != names.len() {
        return Err(Error::internal(format!(
            "predict_all answered {} of {} workloads",
            preds.len(),
            names.len()
        )));
    }
    for p in preds {
        let workload = p.get("workload").and_then(Json::as_str).unwrap_or("");
        let (cli_line, cli_energy) = expected.get(workload).ok_or_else(|| {
            Error::internal(format!("unexpected workload '{workload}' in predict_all"))
        })?;
        let text = p.get("text").and_then(Json::as_str).unwrap_or("");
        if exact && text != *cli_line {
            return Err(Error::internal(format!(
                "{workload}: predict_all line diverged from the CLI\n  served: {text}\n  cli:    {cli_line}"
            )));
        }
        let energy = p.get("energy_j").and_then(Json::as_f64).unwrap_or(f64::NAN);
        if !((energy - cli_energy).abs() <= 1e-4 * cli_energy.abs().max(1.0)) {
            return Err(Error::internal(format!(
                "{workload}: predict_all energy {energy} J vs CLI {cli_energy} J"
            )));
        }
    }
    println!(
        "predict_all answered {} workloads in one response",
        preds.len()
    );
    Ok(())
}

fn send_shutdown(addr: std::net::SocketAddr) -> Result<(), Error> {
    let stream = TcpStream::connect(addr)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    writer.write_all(b"{\"cmd\":\"shutdown\"}\n")?;
    let mut ack = String::new();
    reader.read_line(&mut ack)?;
    if !ack.contains("\"ok\":true") {
        return Err(Error::internal(format!("shutdown not acknowledged: {ack}")));
    }
    Ok(())
}

fn main() -> Result<(), Error> {
    let arts = Artifacts::load_default()
        .map_err(|e| eprintln!("(artifacts unavailable: {e:#}; serving native paths)"))
        .ok();

    // 1. Train a table with the shortened protocol and stage it where the
    //    registry will find it.
    let cfg = ArchConfig::cloudlab_v100();
    let tc = TrainConfig {
        reps: 2,
        bench_secs: 60.0,
        cooldown_secs: 15.0,
        idle_secs: 20.0,
        cov_threshold: 0.02,
    };
    println!("training {} table for the demo...", cfg.name);
    let table = ClusterCampaign::new(cfg.clone(), 4, 42)
        .train(&tc, arts.as_ref())?
        .table;
    let dir = std::env::temp_dir().join("wattchmen_serve_demo");
    std::fs::create_dir_all(&dir)?;
    table.save(&dir.join("cloudlab-v100.table.json"))?;

    // 2. Precompute what `wattchmen predict` would print per workload
    //    (artifact parity requires computing before the artifacts move to
    //    the serving thread — they are not Sync).
    let suite = workloads::evaluation_suite(cfg.gen);
    let expected: Arc<BTreeMap<String, (String, f64)>> = Arc::new(
        suite
            .iter()
            .map(|w| {
                let scaled = scaled_workload(&cfg, w, WORKLOAD_SECS);
                let apps = vec![(w.name.clone(), profile_app(&cfg, &scaled.kernels))];
                let pred = predict_suite(&table, &apps, Mode::Pred, arts.as_ref())?
                    .into_iter()
                    .next()
                    .unwrap();
                Ok((
                    w.name.clone(),
                    (protocol::render_line(&pred), pred.energy_j),
                ))
            })
            .collect::<Result<_, Error>>()?,
    );
    let names: Vec<String> = suite.iter().map(|w| w.name.clone()).collect();
    let exact_parity = arts.is_none();

    // 3. Bind the server; drive the burst from client threads while the
    //    main thread runs the coalescer (where the artifacts live).
    let server = PredictServer::bind(ServeConfig {
        addr: "127.0.0.1:0".into(),
        workers: BURST,
        linger: Duration::from_millis(500),
        tables_dir: dir,
        default_duration_s: WORKLOAD_SECS,
        ..ServeConfig::default()
    })?;
    let addr = server.local_addr();
    println!("wattchmen serve listening on {addr}");

    let burst = thread::spawn(move || {
        let result = run_burst(addr, &names, &expected, exact_parity).and_then(|elapsed| {
            run_predict_all(addr, &names, &expected, exact_parity).map(|()| elapsed)
        });
        // Shut the server down whether or not the burst succeeded — the
        // main thread is blocked in run() until we do.
        let shutdown = send_shutdown(addr);
        result.and_then(|elapsed| shutdown.map(|()| elapsed))
    });

    server.run(arts.as_ref())?;
    let elapsed = burst
        .join()
        .expect("burst thread panicked")
        .map_err(|e| Error::internal(format!("client burst failed: {e}")))?;

    // 4. Assert the burst actually coalesced (≤ ⌈64/32⌉ batched calls).
    let batches = server.batch_calls();
    println!(
        "answered {} predictions in {:.1} ms across {} batched predict call(s)",
        server.served(),
        elapsed.as_secs_f64() * 1e3,
        batches
    );
    // The burst plus the one predict_all suite request.
    if server.served() != BURST + 1 {
        return Err(Error::internal(format!(
            "served {} of {} requests",
            server.served(),
            BURST + 1
        )));
    }
    // ≤ ⌈64/32⌉ for the burst, plus one batch for the predict_all suite.
    let max_batches = BURST.div_ceil(32) + 1;
    if batches > max_batches {
        return Err(Error::internal(format!(
            "burst fanned out into {batches} batched calls (want ≤ {max_batches})"
        )));
    }
    println!("serve_demo: clean shutdown");
    Ok(())
}
