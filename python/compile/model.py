"""Layer-2 JAX compute graph for Wattchmen's training + prediction math.

Four entry points, each AOT-lowered by compile.aot to an HLO-text artifact
that the rust coordinator executes through PJRT (python never runs at
request time):

  nnls(A, b, mask)              -- the paper's non-negative solver (3.1)
  integrate_traces(P, valid, dt)-- steady-state energy integration (3.3)
  affine_fit(x, y, mask)        -- cross-system table transfer (6 / Fig 14)
  predict_energy(C, e, p0, t)   -- batched workload energy prediction (3.5)

The hot inner loops (trace integration, the projected-gradient step) are
Layer-1 Pallas kernels; everything here stays fixed-shape so the lowered
module is a static graph (lax.scan, no retracing).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from compile.kernels.integrate import integrate_traces as _integrate_pallas
from compile.kernels.nnls_step import pgd_step

# Fixed artifact shapes (padded; rust masks the real problem into them).
NNLS_N = 128
NNLS_ITERS = 2000
TRACE_B = 128
TRACE_T = 4096
AFFINE_N = 256
PREDICT_W = 32
PREDICT_I = 256


def _lipschitz(G, iters: int = 50):
    """Largest-eigenvalue estimate of PSD G via fixed-step power iteration."""
    n = G.shape[0]
    v0 = jnp.ones((n,), jnp.float32) / jnp.sqrt(jnp.asarray(n, jnp.float32))

    def body(v, _):
        w = G @ v
        norm = jnp.linalg.norm(w)
        v = jnp.where(norm > 0, w / jnp.maximum(norm, 1e-30), v)
        return v, None

    v, _ = jax.lax.scan(body, v0, None, length=iters)
    return jnp.maximum(v @ (G @ v), 1e-12)


@functools.partial(jax.jit, static_argnames=("iters",))
def nnls(A, b, mask, iters: int = NNLS_ITERS):
    """Non-negative least squares via accelerated projected gradient.

    Args:
      A: f32[N, N] instruction-share matrix (row = microbenchmark, column =
        instruction group; padded rows/cols are zero).
      b: f32[N] per-benchmark dynamic energy (right-hand side).
      mask: f32[N] 1.0 for live columns, 0.0 for padding.  Padded columns
        have zero gradient; masking pins them to exactly zero.
      iters: fixed iteration count (static graph).

    Returns:
      x: f32[N] non-negative solution, zero on padded columns.
    """
    A = A.astype(jnp.float32)
    b = b.astype(jnp.float32)
    mask = mask.astype(jnp.float32)
    G = A.T @ A
    h = A.T @ b
    # Ridge on the padded diagonal keeps G positive definite there without
    # perturbing live columns.
    G = G + jnp.diag(1e-6 * (1.0 - mask))
    alpha = 1.0 / _lipschitz(G)

    x0 = jnp.zeros((A.shape[0],), jnp.float32)

    def body(carry, _):
        x, y, t = carry
        x_new = pgd_step(G, y, h, alpha) * mask
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        y_new = x_new + ((t - 1.0) / t_new) * (x_new - x)
        return (x_new, y_new, t_new), None

    (x, _, _), _ = jax.lax.scan(
        body, (x0, x0, jnp.asarray(1.0, jnp.float32)), None, length=iters
    )
    return x * mask


@jax.jit
def integrate_traces(P, valid, dt):
    """Batched masked trapezoidal integration (see kernels.integrate)."""
    return _integrate_pallas(P, valid, dt)


@jax.jit
def affine_fit(x, y, mask):
    """Masked least-squares line fit y ~ slope*x + intercept.

    Used for the Fig-14 experiment: transfer a per-instruction energy table
    across systems (air-cooled -> water-cooled) from a measured subset.
    """
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    m = mask.astype(jnp.float32)
    n = jnp.maximum(jnp.sum(m), 1.0)
    mx = jnp.sum(x * m) / n
    my = jnp.sum(y * m) / n
    var = jnp.sum((x - mx) ** 2 * m)
    cov = jnp.sum((x - mx) * (y - my) * m)
    slope = cov / jnp.maximum(var, 1e-12)
    return slope, my - slope * mx


@jax.jit
def predict_energy(C, e, p0, t):
    """Batched workload energy: E_w = p0_w * t_w + C[w,:] @ e.

    C is in giga-instructions per group, e in nJ/instruction, so C @ e is in
    joules; p0 is the (constant + static) power in watts and t the runtime
    in seconds.
    """
    C = C.astype(jnp.float32)
    e = e.astype(jnp.float32)
    return p0.astype(jnp.float32) * t.astype(jnp.float32) + C @ e
