"""AOT bridge: lower the Layer-2 entry points to HLO-text artifacts.

HLO *text* (not ``lowered.compile().serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
xla_extension 0.5.1 (the version the published ``xla`` 0.1.6 crate binds)
rejects with ``proto.id() <= INT_MAX``.  The text parser reassigns ids and
round-trips cleanly — see /opt/xla-example/gen_hlo.py.

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def artifact_specs():
    """Name -> (function, example argument specs)."""
    n, B, T = model.NNLS_N, model.TRACE_B, model.TRACE_T
    an, W, I = model.AFFINE_N, model.PREDICT_W, model.PREDICT_I
    return {
        f"nnls_{n}": (
            model.nnls,
            (_spec((n, n)), _spec((n,)), _spec((n,))),
        ),
        f"integrate_{B}x{T}": (
            model.integrate_traces,
            (_spec((B, T)), _spec((B, T)), _spec(())),
        ),
        f"affine_fit_{an}": (
            model.affine_fit,
            (_spec((an,)), _spec((an,)), _spec((an,))),
        ),
        f"predict_{W}x{I}": (
            model.predict_energy,
            (_spec((W, I)), _spec((I,)), _spec((W,)), _spec((W,))),
        ),
    }


def build(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {}
    for name, (fn, specs) in artifact_specs().items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
            ],
            "chars": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {out_dir}/manifest.json")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact dir")
    args = ap.parse_args()
    build(args.out)


if __name__ == "__main__":
    main()
