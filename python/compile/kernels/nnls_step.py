"""Layer-1 Pallas kernel: one projected-gradient NNLS iteration.

Wattchmen solves a square system of microbenchmark energy equations
``A x = b`` subject to ``x >= 0`` (paper section 3.1: a non-negative solver
over the instruction-share matrix).  The L2 graph reduces the problem to the
normal equations ``G = A^T A``, ``h = A^T b`` and iterates the accelerated
projected-gradient step

    x_new = max(0, y - alpha * (G @ y - h))

This kernel computes a single step.  At N=128 the entire G tile is
128*128*4 = 64 KiB -- a single VMEM-resident block, so the matvec hits the
MXU once per iteration with no HBM traffic beyond the initial load.  For
larger tables the kernel would tile G by rows; N=128 comfortably covers the
paper's 90-instruction V100 system and the A100/H100 variants.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pgd_step_kernel(g_ref, y_ref, h_ref, alpha_ref, o_ref):
    """x_new = max(0, y - alpha * (G y - h)) over a full (N, N) block."""
    g = g_ref[...]
    y = y_ref[...]          # (1, N) row vector
    h = h_ref[...]
    alpha = alpha_ref[0, 0]
    grad = y @ g.T - h      # (1,N) @ (N,N)^T == (G @ y^T)^T
    o_ref[...] = jnp.maximum(y - alpha * grad, 0.0)


@jax.jit
def pgd_step(G, y, h, alpha):
    """One projected-gradient step.

    Args:
      G: f32[N, N] normal matrix A^T A (symmetric PSD).
      y: f32[N] current (extrapolated) iterate.
      h: f32[N] A^T b.
      alpha: scalar step size (1 / L with L >= lambda_max(G)).

    Returns:
      f32[N] next iterate, elementwise non-negative.
    """
    N = G.shape[0]
    y2 = y.reshape(1, N).astype(jnp.float32)
    h2 = h.reshape(1, N).astype(jnp.float32)
    a2 = jnp.asarray(alpha, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        _pgd_step_kernel,
        in_specs=[
            pl.BlockSpec((N, N), lambda: (0, 0)),
            pl.BlockSpec((1, N), lambda: (0, 0)),
            pl.BlockSpec((1, N), lambda: (0, 0)),
            pl.BlockSpec((1, 1), lambda: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, N), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, N), jnp.float32),
        interpret=True,
    )(G.astype(jnp.float32), y2, h2, a2)
    return out.reshape(N)
