"""Layer-1 Pallas kernel: batched power-trace integration.

Every microbenchmark in the Wattchmen training campaign produces a power
trace (NVML-style samples).  Training integrates each trace to energy
(trapezoidal rule over masked sample intervals) and computes the mean power
over the steady-state window.  This is the numeric hot spot of the training
phase: a campaign is O(100) benchmarks x O(5) repetitions x O(2-4k) samples.

The kernel processes a (BLOCK_B, T) tile of traces per grid step.  A full
row lives in VMEM: at T=4096 f32 a row is 16 KiB, so BLOCK_B=8 keeps the
working set (P, V, partials) at ~256 KiB  -- comfortably inside a TPU
core's ~16 MiB VMEM with room for double buffering.  The kernel is
reduction-bound (no MXU use); on TPU it would be VPU/memory-bound, which is
fine because it runs on the build/training path, not per-request.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO that the rust runtime
executes via the `xla` crate.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 8


def _integrate_kernel(p_ref, v_ref, sum_ref, mean_ref):
    """Per-tile body.

    p_ref: (BB, T) power samples [W]
    v_ref: (BB, T) validity mask in {0,1} (1 = sample inside the window)
    sum_ref: (BB,) sum of trapezoid pairs (caller multiplies by dt)
    mean_ref: (BB,) masked mean power over the window
    """
    p = p_ref[...]
    v = v_ref[...]
    # Trapezoid weights: an interval contributes iff both endpoints are valid.
    pair = 0.5 * (p[:, :-1] + p[:, 1:]) * (v[:, :-1] * v[:, 1:])
    sum_ref[...] = jnp.sum(pair, axis=1)
    denom = jnp.maximum(jnp.sum(v, axis=1), 1.0)
    mean_ref[...] = jnp.sum(p * v, axis=1) / denom


@functools.partial(jax.jit, static_argnames=("block_b",))
def integrate_traces(P, valid, dt, block_b: int = DEFAULT_BLOCK_B):
    """Integrate a batch of power traces.

    Args:
      P: f32[B, T] power samples in watts.
      valid: f32[B, T] mask, 1.0 where the sample is inside the integration
        window (rows may have ragged true lengths; tail is zero-padded).
      dt: scalar sample period in seconds.
      block_b: rows per pallas grid step.

    Returns:
      (energy, mean_power): f32[B] joules over the window, f32[B] watts.
    """
    B, T = P.shape
    if B % block_b != 0:
        pad = block_b - B % block_b
        P = jnp.pad(P, ((0, pad), (0, 0)))
        valid = jnp.pad(valid, ((0, pad), (0, 0)))
    Bp = P.shape[0]
    grid = (Bp // block_b,)
    sums, means = pl.pallas_call(
        _integrate_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, T), lambda i: (i, 0)),
            pl.BlockSpec((block_b, T), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp,), jnp.float32),
            jax.ShapeDtypeStruct((Bp,), jnp.float32),
        ],
        interpret=True,
    )(P.astype(jnp.float32), valid.astype(jnp.float32))
    energy = sums[:B] * dt
    return energy, means[:B]
