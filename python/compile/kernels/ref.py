"""Pure-jnp / numpy oracles for the Pallas kernels and the L2 graph.

These are the correctness ground truth: pytest checks every kernel and every
AOT entry point against these implementations (plus scipy.optimize.nnls for
the solver).
"""

from __future__ import annotations

import numpy as np


def integrate_traces_ref(P, valid, dt):
    """Masked trapezoidal integration + masked mean, row-wise.

    Mirrors kernels.integrate.integrate_traces: an interval [t, t+1]
    contributes iff both endpoint samples are valid.
    """
    P = np.asarray(P, np.float64)
    V = np.asarray(valid, np.float64)
    pair = 0.5 * (P[:, :-1] + P[:, 1:]) * (V[:, :-1] * V[:, 1:])
    energy = pair.sum(axis=1) * float(dt)
    denom = np.maximum(V.sum(axis=1), 1.0)
    mean = (P * V).sum(axis=1) / denom
    return energy.astype(np.float32), mean.astype(np.float32)


def pgd_step_ref(G, y, h, alpha):
    """max(0, y - alpha*(G y - h)) in float64 for tight comparison."""
    G = np.asarray(G, np.float64)
    y = np.asarray(y, np.float64)
    h = np.asarray(h, np.float64)
    return np.maximum(y - float(alpha) * (G @ y - h), 0.0).astype(np.float32)


def nnls_ref(A, b, iters=4000):
    """Accelerated projected-gradient NNLS, numpy mirror of model.nnls."""
    A = np.asarray(A, np.float64)
    b = np.asarray(b, np.float64)
    G = A.T @ A
    h = A.T @ b
    # Power iteration for the Lipschitz constant (matches model.nnls).
    v = np.ones(G.shape[0]) / np.sqrt(G.shape[0])
    for _ in range(50):
        w = G @ v
        n = np.linalg.norm(w)
        if n == 0:
            break
        v = w / n
    L = max(float(v @ (G @ v)), 1e-12)
    alpha = 1.0 / L
    x = np.zeros(G.shape[0])
    y = x.copy()
    t = 1.0
    for _ in range(iters):
        x_new = np.maximum(y - alpha * (G @ y - h), 0.0)
        t_new = 0.5 * (1.0 + np.sqrt(1.0 + 4.0 * t * t))
        y = x_new + ((t - 1.0) / t_new) * (x_new - x)
        x, t = x_new, t_new
    return x.astype(np.float32)


def affine_fit_ref(x, y, mask):
    """Masked least-squares line fit: returns (slope, intercept)."""
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    m = np.asarray(mask, np.float64)
    n = max(m.sum(), 1.0)
    mx = (x * m).sum() / n
    my = (y * m).sum() / n
    var = ((x - mx) ** 2 * m).sum()
    cov = ((x - mx) * (y - my) * m).sum()
    slope = cov / max(var, 1e-12)
    return np.float32(slope), np.float32(my - slope * mx)


def predict_energy_ref(C, e, p0, t):
    """E_w = p0_w * t_w + sum_i C[w,i] * e[i]  (C in giga-instructions,
    e in nJ per instruction => the product is joules)."""
    C = np.asarray(C, np.float64)
    e = np.asarray(e, np.float64)
    p0 = np.asarray(p0, np.float64)
    t = np.asarray(t, np.float64)
    return (p0 * t + C @ e).astype(np.float32)
