"""AOT artifact sanity: lowering is deterministic, text parses, manifest OK."""

import json
import os

import pytest

import jax

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_artifact_specs_cover_all_entry_points():
    names = set(aot.artifact_specs().keys())
    assert names == {
        f"nnls_{model.NNLS_N}",
        f"integrate_{model.TRACE_B}x{model.TRACE_T}",
        f"affine_fit_{model.AFFINE_N}",
        f"predict_{model.PREDICT_W}x{model.PREDICT_I}",
    }


@pytest.mark.parametrize("name", sorted(aot.artifact_specs().keys()))
def test_lowering_produces_valid_hlo_text(name):
    fn, specs = aot.artifact_specs()[name]
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert "ENTRY" in text and "HloModule" in text
    # No Mosaic custom-calls: interpret-mode pallas must lower to plain HLO
    # or the rust CPU PJRT client cannot execute the artifact.
    assert "mosaic" not in text.lower()


@pytest.mark.skipif(not os.path.isdir(ART), reason="run `make artifacts` first")
def test_built_artifacts_match_manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    assert set(manifest.keys()) == set(aot.artifact_specs().keys())
    for name, meta in manifest.items():
        path = os.path.join(ART, meta["file"])
        assert os.path.isfile(path), path
        with open(path) as f:
            text = f.read()
        assert "ENTRY" in text
        assert len(text) == meta["chars"]
