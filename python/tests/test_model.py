"""L2 graph correctness: nnls vs scipy, affine_fit, predict_energy."""

import numpy as np
import pytest
import scipy.optimize
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

SETTINGS = dict(max_examples=10, deadline=None)


def _padded_system(A, b):
    """Embed a small system into the fixed artifact shape with a mask."""
    n = model.NNLS_N
    k = A.shape[0]
    Ap = np.zeros((n, n), np.float32)
    bp = np.zeros(n, np.float32)
    mp = np.zeros(n, np.float32)
    Ap[:k, :k] = A
    bp[:k] = b
    mp[:k] = 1.0
    return Ap, bp, mp


def _wattchmen_like_system(k, seed):
    """Diagonally-dominant instruction-share system like the real campaign:
    each benchmark is ~85% one target instruction + ancillary spread."""
    rng = np.random.default_rng(seed)
    A = np.zeros((k, k))
    for i in range(k):
        A[i, i] = rng.uniform(0.6, 0.95)
        anc = rng.dirichlet(np.ones(k - 1)) * (1.0 - A[i, i]) if k > 1 else []
        A[i, np.arange(k) != i] = anc
    x_true = rng.uniform(0.2, 5.0, size=k)
    return A.astype(np.float32), x_true


class TestNnls:
    @settings(**SETTINGS)
    @given(k=st.integers(2, 40), seed=st.integers(0, 2**31 - 1))
    def test_recovers_true_solution(self, k, seed):
        A, x_true = _wattchmen_like_system(k, seed)
        b = (A.astype(np.float64) @ x_true).astype(np.float32)
        Ap, bp, mp = _padded_system(A, b)
        x = np.asarray(model.nnls(Ap, bp, mp, iters=1500))
        np.testing.assert_allclose(x[:k], x_true, rtol=5e-3, atol=5e-3)
        assert np.all(x[k:] == 0.0)

    @settings(**SETTINGS)
    @given(k=st.integers(2, 24), seed=st.integers(0, 2**31 - 1))
    def test_matches_scipy_nnls(self, k, seed):
        rng = np.random.default_rng(seed)
        A = rng.uniform(0.0, 1.0, size=(k, k)).astype(np.float32)
        A += k * np.eye(k, dtype=np.float32)  # well-conditioned
        b = rng.uniform(-1.0, 2.0, size=k).astype(np.float32)
        Ap, bp, mp = _padded_system(A, b)
        x = np.asarray(model.nnls(Ap, bp, mp, iters=2000))
        x_sp, _ = scipy.optimize.nnls(A.astype(np.float64), b.astype(np.float64))
        np.testing.assert_allclose(x[:k], x_sp, rtol=1e-2, atol=1e-3)

    def test_nonnegative_output(self):
        rng = np.random.default_rng(0)
        A = rng.normal(size=(30, 30)).astype(np.float32)
        b = rng.normal(size=30).astype(np.float32)
        Ap, bp, mp = _padded_system(A, b)
        x = np.asarray(model.nnls(Ap, bp, mp, iters=500))
        assert np.all(x >= 0.0)

    def test_matches_numpy_mirror(self):
        A, x_true = _wattchmen_like_system(20, 42)
        b = (A.astype(np.float64) @ x_true).astype(np.float32)
        Ap, bp, mp = _padded_system(A, b)
        x = np.asarray(model.nnls(Ap, bp, mp, iters=1000))
        x_np = ref.nnls_ref(A, b, iters=1000)
        np.testing.assert_allclose(x[:20], x_np, rtol=1e-3, atol=1e-3)


class TestAffineFit:
    @settings(**SETTINGS)
    @given(
        k=st.integers(3, 256),
        slope=st.floats(-3.0, 3.0),
        icept=st.floats(-5.0, 5.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_exact_line_recovery(self, k, slope, icept, seed):
        rng = np.random.default_rng(seed)
        x = np.zeros(model.AFFINE_N, np.float32)
        y = np.zeros(model.AFFINE_N, np.float32)
        m = np.zeros(model.AFFINE_N, np.float32)
        xv = rng.uniform(-10, 10, size=k).astype(np.float32)
        if np.var(xv) < 1e-3:
            xv = xv + np.linspace(0, 1, k, dtype=np.float32)
        x[:k] = xv
        y[:k] = slope * xv + icept
        m[:k] = 1.0
        s, i = model.affine_fit(x, y, m)
        assert abs(float(s) - slope) < 5e-2 + 2e-2 * abs(slope)
        assert abs(float(i) - icept) < 1e-1 + 2e-2 * abs(icept)

    def test_matches_ref_noisy(self):
        rng = np.random.default_rng(5)
        x = rng.uniform(0, 20, model.AFFINE_N).astype(np.float32)
        y = (0.9 * x + 1.7 + rng.normal(0, 0.3, model.AFFINE_N)).astype(np.float32)
        m = (rng.uniform(size=model.AFFINE_N) < 0.6).astype(np.float32)
        s, i = model.affine_fit(x, y, m)
        s_ref, i_ref = ref.affine_fit_ref(x, y, m)
        np.testing.assert_allclose(float(s), s_ref, rtol=1e-4)
        np.testing.assert_allclose(float(i), i_ref, rtol=1e-3, atol=1e-4)


class TestPredict:
    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, seed):
        rng = np.random.default_rng(seed)
        C = rng.uniform(0, 100, (model.PREDICT_W, model.PREDICT_I)).astype(np.float32)
        e = rng.uniform(0, 5, model.PREDICT_I).astype(np.float32)
        p0 = rng.uniform(50, 150, model.PREDICT_W).astype(np.float32)
        t = rng.uniform(0.1, 300, model.PREDICT_W).astype(np.float32)
        out = np.asarray(model.predict_energy(C, e, p0, t))
        expect = ref.predict_energy_ref(C, e, p0, t)
        np.testing.assert_allclose(out, expect, rtol=2e-4)

    def test_zero_counts_is_static_only(self):
        C = np.zeros((model.PREDICT_W, model.PREDICT_I), np.float32)
        e = np.ones(model.PREDICT_I, np.float32)
        p0 = np.full(model.PREDICT_W, 80.0, np.float32)
        t = np.full(model.PREDICT_W, 10.0, np.float32)
        out = np.asarray(model.predict_energy(C, e, p0, t))
        np.testing.assert_allclose(out, 800.0, rtol=1e-6)
