"""L1 Pallas kernels vs pure references — the core correctness signal.

hypothesis sweeps shapes/values; assert_allclose against ref.py.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.integrate import integrate_traces
from compile.kernels.nnls_step import pgd_step
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def _traces(b, t, seed):
    rng = np.random.default_rng(seed)
    P = rng.uniform(20.0, 320.0, size=(b, t)).astype(np.float32)
    # Ragged validity windows: a contiguous [lo, hi) window per row.
    V = np.zeros((b, t), np.float32)
    for i in range(b):
        lo = int(rng.integers(0, max(t // 2, 1)))
        hi = int(rng.integers(lo + 1, t + 1))
        V[i, lo:hi] = 1.0
    return P, V


class TestIntegrate:
    @settings(**SETTINGS)
    @given(
        b=st.integers(1, 12),
        t=st.integers(2, 300),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_random_shapes(self, b, t, seed):
        P, V = _traces(b, t, seed)
        dt = 0.1
        e, m = integrate_traces(P, V, dt, block_b=4)
        e_ref, m_ref = ref.integrate_traces_ref(P, V, dt)
        np.testing.assert_allclose(e, e_ref, rtol=2e-5, atol=1e-3)
        np.testing.assert_allclose(m, m_ref, rtol=2e-5, atol=1e-4)

    def test_artifact_shape(self):
        P, V = _traces(128, 4096, 7)
        e, m = integrate_traces(P, V, 0.1)
        e_ref, m_ref = ref.integrate_traces_ref(P, V, 0.1)
        np.testing.assert_allclose(e, e_ref, rtol=2e-5, atol=5e-2)
        np.testing.assert_allclose(m, m_ref, rtol=2e-5, atol=1e-3)

    def test_all_invalid_rows_are_zero(self):
        P = np.full((4, 64), 150.0, np.float32)
        V = np.zeros((4, 64), np.float32)
        e, m = integrate_traces(P, V, 0.1)
        assert np.all(np.asarray(e) == 0.0)
        assert np.all(np.asarray(m) == 0.0)

    def test_constant_power_full_window(self):
        # Constant P over a fully-valid window: E = P * (T-1) * dt exactly.
        P = np.full((2, 101), 200.0, np.float32)
        V = np.ones((2, 101), np.float32)
        e, m = integrate_traces(P, V, 0.5)
        np.testing.assert_allclose(e, 200.0 * 100 * 0.5, rtol=1e-6)
        np.testing.assert_allclose(m, 200.0, rtol=1e-6)

    def test_single_valid_sample_has_zero_energy(self):
        P = np.full((1, 16), 99.0, np.float32)
        V = np.zeros((1, 16), np.float32)
        V[0, 5] = 1.0
        e, m = integrate_traces(P, V, 0.1)
        np.testing.assert_allclose(e, 0.0, atol=1e-6)
        np.testing.assert_allclose(m, 99.0, rtol=1e-6)


class TestPgdStep:
    @settings(**SETTINGS)
    @given(n=st.integers(2, 96), seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, n, seed):
        rng = np.random.default_rng(seed)
        A = rng.normal(size=(n, n)).astype(np.float32)
        G = (A @ A.T).astype(np.float32)
        y = rng.normal(size=n).astype(np.float32)
        h = rng.normal(size=n).astype(np.float32)
        alpha = float(rng.uniform(1e-4, 1e-1))
        out = pgd_step(G, y, h, alpha)
        expect = ref.pgd_step_ref(G, y, h, alpha)
        np.testing.assert_allclose(out, expect, rtol=2e-4, atol=2e-4)

    def test_result_nonnegative(self):
        rng = np.random.default_rng(3)
        A = rng.normal(size=(128, 128)).astype(np.float32)
        G = A @ A.T
        out = pgd_step(G, rng.normal(size=128).astype(np.float32),
                       rng.normal(size=128).astype(np.float32), 0.01)
        assert np.all(np.asarray(out) >= 0.0)

    def test_fixed_point_of_interior_solution(self):
        # If y solves G y = h with y > 0, the step leaves it unchanged.
        rng = np.random.default_rng(11)
        Q = rng.normal(size=(32, 32))
        G = (Q @ Q.T + 32 * np.eye(32)).astype(np.float32)
        y = rng.uniform(0.5, 1.5, size=32).astype(np.float32)
        h = (G.astype(np.float64) @ y).astype(np.float32)
        out = pgd_step(G, y, h, 1e-3)
        np.testing.assert_allclose(out, y, rtol=5e-4, atol=5e-4)
